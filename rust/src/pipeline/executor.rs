//! The pipelined executor — paper Sec. 3.3, generalized to
//! cross-request micro-batches.
//!
//! Text-to-image under a device memory budget:
//!
//! 1. acquire the denoising UNet (cached across requests);
//! 2. acquire the text encoder, encode each request's cond prompt (the
//!    uncond `""` context is computed once and cached across requests
//!    per weights tag), evict it;
//! 3. start the decoder prefetch on a child thread and run the denoise
//!    loop (each row advanced by its [`Sampler`]'s solver), polling the
//!    prefetch between steps;
//! 4. finalize the decoder (device compile + upload), decode each
//!    request, evict.
//!
//! The denoise loop is **batched**: all requests of a compatible group
//! (same UNet executable, see [`crate::pipeline::batch`]) share one
//! CFG-batched dispatch per step, with per-request timesteps and
//! host-side per-request guidance.  Requests on shorter schedules
//! leave the batch when their schedule ends; the stragglers continue
//! (eventually solo).  A solo `generate` is simply a batch of one, so
//! batched and solo runs share every line of arithmetic — which is
//! what makes them bit-identical.
//!
//! The step loop runs on a reusable device-buffer plan
//! ([`crate::pipeline::batch::StepBuffers`]): buffers are created once
//! per batch composition and rewritten in place each step — no per-step
//! `clone()`s, `vec![t]`s, or fresh device buffers.
//!
//! On top of the run-to-completion batch path sits **step-level
//! continuous batching** ([`PipelinedExecutor::run_continuous`]): a
//! session whose row membership changes at step boundaries — joiners
//! splice in, finished rows decode immediately and free their slot,
//! low-priority rows checkpoint out under deadline pressure (see
//! [`crate::pipeline::continuous`]).  Both paths share the same
//! per-member arithmetic, so continuous rows keep the bit-identical-
//! to-solo guarantee.
//!
//! Peak memory ~= unet + max(text_encoder, decoder) instead of the sum
//! of all three (the non-pipelined baseline, also implemented here for
//! the Fig. 4 / ablation comparison).
//!
//! All load/evict/ledger policy lives in
//! [`crate::pipeline::residency::ResidencyManager`]; this module is
//! pure stage orchestration.  Per-request overrides (step count,
//! variant, guidance) arrive via [`ExecOverrides`] so a serving layer
//! can honor them end-to-end without rebuilding the executor.
//!
//! Component loads are two-tier: the host half (read/parse/dequant)
//! comes from a process-wide [`ArtifactStore`] shared by every fleet
//! worker, and eviction keeps the compiled executable in the residency
//! warm tier — so a post-eviction re-acquire pays only the device
//! upload.  Every load is accounted per stage in the executor's
//! [`LoadProfile`], whose per-request deltas ride on
//! [`StageTimings::loads`] up into the pool metrics and back into the
//! planner's overhead term.

use std::collections::BTreeMap;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use crate::delegate::DeviceProfile;
use crate::error::{Error, Result};
use crate::planner::{FleetCalibration, Observation, StageSig};
use crate::pipeline::batch::{form_batches, BatchKey, BatchRequest, StepBuffers};
use crate::pipeline::continuous::{
    Checkpoint, ContinuousControl, ContinuousJob, LiveRow, SessionStats,
};
use crate::pipeline::loader::Prefetcher;
use crate::pipeline::residency::{PinGuard, ResidencyManager, Retention};
use crate::pipeline::trace::MemoryTrace;
use crate::runtime::{
    ActInput, ArtifactStore, Component, Engine, LoadStats, Manifest, WarmExecutable,
};
use crate::scheduler::{guide, Ddim, Sampler};
use crate::tokenizer;
use crate::util::rng::Rng;

/// A cached component handle (reference-counted: the residency cache
/// and in-flight stages share ownership within a worker thread).
pub type ResidentComponent = Rc<Component>;

/// Weights tag of the text encoder and decoder (only the UNet ships
/// multiple precisions).
const AUX_TAG: &str = "fp32";

#[derive(Debug, Clone)]
pub struct ExecOptions {
    /// device memory budget in bytes (ledger-enforced)
    pub memory_budget: usize,
    /// pipelined (paper) vs load-everything-up-front baseline
    pub pipelined: bool,
    /// weight precision tag for the UNet ("fp32" | "int8" | "int8_pruned")
    pub unet_weights: String,
    pub num_steps: usize,
    pub guidance_scale: f64,
    /// default solver for requests without a sampler override
    pub sampler: Sampler,
    /// compiled executables kept per worker across evictions (the warm
    /// reload tier); 0 disables warm reuse entirely
    pub warm_slots: usize,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions {
            memory_budget: usize::MAX,
            pipelined: true,
            unet_weights: "fp32".into(),
            num_steps: 20,
            guidance_scale: 7.5,
            sampler: Sampler::Ddim,
            warm_slots: 8,
        }
    }
}

/// Cumulative per-executor load accounting across every component
/// (re)load, split by stage — the *observed* counterpart of the
/// planner's modeled per-request overhead term.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LoadProfile {
    /// loads that compiled from scratch
    pub cold_loads: u64,
    /// loads that reused a warm-tier executable (upload only)
    pub warm_reloads: u64,
    /// host halves served from the artifact store cache
    pub store_hits: u64,
    /// host halves this executor paid disk read/parse/dequant for
    pub store_misses: u64,
    pub read_s: f64,
    pub parse_s: f64,
    pub dequant_s: f64,
    pub compile_s: f64,
    pub upload_s: f64,
}

impl LoadProfile {
    pub fn record(&mut self, s: &LoadStats) {
        if s.warm {
            self.warm_reloads += 1;
        } else {
            self.cold_loads += 1;
        }
        if s.store_hit {
            self.store_hits += 1;
        } else {
            self.store_misses += 1;
        }
        self.read_s += s.read_s;
        self.parse_s += s.parse_s;
        self.dequant_s += s.dequant_s;
        self.compile_s += s.compile_s;
        self.upload_s += s.upload_s;
    }

    /// Total component (re)loads.
    pub fn loads(&self) -> u64 {
        self.cold_loads + self.warm_reloads
    }

    /// Wall seconds spent across every load stage.
    pub fn total_s(&self) -> f64 {
        self.read_s + self.parse_s + self.dequant_s + self.compile_s + self.upload_s
    }

    /// Host-stage seconds (read + parse + dequant) — zero on a pure
    /// store-hit / warm-reload path.
    pub fn host_s(&self) -> f64 {
        self.read_s + self.parse_s + self.dequant_s
    }

    /// A batch member's slice of a shared load delta: the timed stages
    /// are amortized evenly over the `n` members (so per-request
    /// latency percentiles aren't skewed by whoever happened to be
    /// listed first), while the integer load/hit counters stay whole
    /// on the first member — fleet totals must count each load once,
    /// not `n` fractional times.
    pub fn share(&self, n: usize, first: bool) -> LoadProfile {
        let n = n.max(1) as f64;
        LoadProfile {
            cold_loads: if first { self.cold_loads } else { 0 },
            warm_reloads: if first { self.warm_reloads } else { 0 },
            store_hits: if first { self.store_hits } else { 0 },
            store_misses: if first { self.store_misses } else { 0 },
            read_s: self.read_s / n,
            parse_s: self.parse_s / n,
            dequant_s: self.dequant_s / n,
            compile_s: self.compile_s / n,
            upload_s: self.upload_s / n,
        }
    }

    /// What accumulated since an `earlier` snapshot of the same
    /// profile (per-request deltas for the stage timings).
    pub fn since(&self, earlier: &LoadProfile) -> LoadProfile {
        LoadProfile {
            cold_loads: self.cold_loads - earlier.cold_loads,
            warm_reloads: self.warm_reloads - earlier.warm_reloads,
            store_hits: self.store_hits - earlier.store_hits,
            store_misses: self.store_misses - earlier.store_misses,
            read_s: self.read_s - earlier.read_s,
            parse_s: self.parse_s - earlier.parse_s,
            dequant_s: self.dequant_s - earlier.dequant_s,
            compile_s: self.compile_s - earlier.compile_s,
            upload_s: self.upload_s - earlier.upload_s,
        }
    }
}

/// Feeds the online roofline calibrator: one latency observation per
/// device dispatch, tagged with the planner's modeled work signature
/// for this worker's device class (see
/// [`crate::planner::Calibrator`]).  Installed by the serving layer on
/// fleet workers; executors without one record nothing.
#[derive(Clone)]
pub struct DispatchObserver {
    /// fleet-shared calibration windows, keyed by device class
    pub sink: FleetCalibration,
    /// registry name of the class this worker's dispatches calibrate
    pub class: String,
    /// shipped roofline constants of the class (the fit's anchor)
    pub base: DeviceProfile,
    /// `[text, unet, decode]` stage signatures per variant
    pub sigs: BTreeMap<String, [StageSig; 3]>,
}

impl DispatchObserver {
    /// Record one dispatch; `rows` scales the batch-1 signature to the
    /// work actually dispatched.
    fn observe(&self, sig: &StageSig, rows: usize, seconds: f64) {
        let r = rows.max(1) as f64;
        self.sink.record(
            &self.class,
            &self.base,
            Observation {
                class: sig.class,
                flops: sig.flops * r,
                bytes: sig.bytes * r,
                seconds,
            },
        );
    }
}

/// Per-request overrides of the configured [`ExecOptions`] defaults —
/// a request on a distilled schedule can run 4 steps while the server
/// default stays 20.
#[derive(Debug, Clone, Default)]
pub struct ExecOverrides {
    pub num_steps: Option<usize>,
    pub variant: Option<String>,
    pub guidance_scale: Option<f64>,
    /// solver selection; distilled members also pin the step count
    pub sampler: Option<Sampler>,
}

#[derive(Debug, Clone, Default)]
pub struct StageTimings {
    pub text_load_s: f64,
    pub text_encode_s: f64,
    pub unet_load_s: f64,
    pub denoise_s: f64,
    pub denoise_steps: usize,
    pub decoder_load_s: f64,
    pub decode_s: f64,
    pub total_s: f64,
    /// this request's time-weighted share of the worker's wall: each
    /// dispatch's wall divided by the rows live *in that dispatch*,
    /// plus the request's slice of the shared non-denoise stages.
    /// Unlike `total_s / occupancy`, this stays truthful when rows
    /// join and leave mid-flight.  0.0 from executors that predate the
    /// accounting (mocks) — consumers fall back to formation-time
    /// occupancy then.
    pub busy_share_s: f64,
    /// stage-level load accounting for this request.  Timed load work
    /// shared by a micro-batch is amortized across its members; the
    /// load *counters* are charged to the first member so fleet-level
    /// totals match what actually happened, not occupancy-multiplied.
    pub loads: LoadProfile,
}

pub struct GenerateResult {
    /// HWC RGB f32 image in roughly [-1, 1]
    pub image: Vec<f32>,
    pub image_size: usize,
    /// final latent (for numeric comparisons across variants)
    pub latent: Vec<f32>,
    pub timings: StageTimings,
    pub peak_memory: usize,
}

pub struct PipelinedExecutor {
    pub engine: Engine,
    pub manifest: Manifest,
    pub residency: ResidencyManager<ResidentComponent, WarmExecutable>,
    pub options: ExecOptions,
    /// process-wide host-artifact cache, shared across fleet workers
    store: Arc<ArtifactStore>,
    /// cumulative stage-level load accounting for this executor
    profile: LoadProfile,
    /// DDIM built once from the manifest and reused by every request
    /// (guidance is applied host-side per request, not by the sampler).
    ddim: Ddim,
    /// uncond ("") text context, reused across requests; invalidated
    /// when the encoder is evicted from the cache (`evict_idle`,
    /// failure purge).  The text encoder ships a single weights tag
    /// ([`AUX_TAG`]), so one slot covers the (component, tag) key; a
    /// multi-precision encoder would widen this to a keyed map.
    uncond_ctx: Option<Rc<Vec<f32>>>,
    /// per-dispatch latency sink for online roofline calibration
    observer: Option<DispatchObserver>,
}

/// One request's denoise-loop state inside a batch.
struct Member {
    /// the solver advancing this row
    sampler: Sampler,
    /// per-request step schedule (descending timesteps)
    ts: Vec<usize>,
    guidance: f64,
    latent: Vec<f32>,
    eps: Vec<f32>,
    cond: Vec<f32>,
    /// the solver's bounded history of previous eps predictions
    /// (oldest first; empty for first-order samplers)
    history: Vec<Vec<f32>>,
}

impl Member {
    /// One solver update at schedule index `pos`, then record this
    /// step's eps into the bounded history.  `ts[pos - 1]` is the
    /// timestep the newest history entry was predicted at — recovered
    /// from the checkpointed `(ts, pos)` on resume, so a resumed row
    /// runs exactly the uninterrupted arithmetic.
    fn advance(&mut self, ddim: &Ddim, pos: usize) {
        let t_prev = self.ts.get(pos + 1).copied();
        let t_last = if pos > 0 { Some(self.ts[pos - 1]) } else { None };
        self.sampler.step(
            ddim,
            &mut self.latent,
            &self.eps,
            &self.history,
            self.ts[pos],
            t_prev,
            t_last,
        );
        self.sampler.remember(&mut self.history, &self.eps);
    }
}

/// One row of a continuous session: a [`Member`] plus the lifecycle
/// state that lets it enter, leave, checkpoint and resume
/// independently of its batchmates.
struct LiveMember {
    token: u64,
    req: BatchRequest,
    m: Member,
    /// next schedule index to run (steps `0..pos` already applied)
    pos: usize,
    /// time-weighted worker share attributed so far (carried across
    /// preemptions)
    busy_s: f64,
    /// denoise wall attributed so far (carried across preemptions)
    denoise_s: f64,
    /// admission into *this* session (total_s covers the current
    /// session only; queue time is the scheduler's to account)
    start: Instant,
}

struct StageOutput {
    image: Vec<f32>,
    latent: Vec<f32>,
    steps: usize,
    /// time-weighted denoise share: Σ over the member's live steps of
    /// step_wall / rows_live_that_step
    busy_denoise_s: f64,
}

impl PipelinedExecutor {
    /// Executor with a private artifact store (single-worker runs,
    /// offline tools).  Fleet workers share one store instead — see
    /// [`Self::with_store`].
    pub fn new(manifest: Manifest, options: ExecOptions) -> Result<PipelinedExecutor> {
        let store = Arc::new(ArtifactStore::new());
        Self::with_store(manifest, options, store)
    }

    /// Executor over a shared host-artifact store: N workers built on
    /// the same store read and parse each `(component, tag)` from disk
    /// exactly once between them.
    pub fn with_store(
        manifest: Manifest,
        options: ExecOptions,
        store: Arc<ArtifactStore>,
    ) -> Result<PipelinedExecutor> {
        let engine = Engine::new()?;
        // eviction demotes the compiled executable into the warm tier;
        // a later re-acquire pays only the device upload
        let residency = ResidencyManager::with_warm_tier(
            options.memory_budget,
            options.warm_slots,
            |c: &ResidentComponent| c.executable(),
        );
        let ddim = Ddim::from_alphas(
            manifest.scheduler.params.clone(),
            manifest.scheduler.alphas_cumprod.clone(),
        );
        Ok(PipelinedExecutor {
            engine,
            manifest,
            residency,
            options,
            store,
            profile: LoadProfile::default(),
            ddim,
            uncond_ctx: None,
            observer: None,
        })
    }

    /// Install the calibration sink this executor reports each
    /// dispatch's (work signature, wall) to.
    pub fn set_observer(&mut self, observer: DispatchObserver) {
        self.observer = Some(observer);
    }

    /// The installed calibration sink, if any.
    pub fn observer(&self) -> Option<&DispatchObserver> {
        self.observer.as_ref()
    }

    /// The shared host-artifact store this executor loads through.
    pub fn store(&self) -> &Arc<ArtifactStore> {
        &self.store
    }

    /// Cumulative stage-level load accounting since construction.
    pub fn load_profile(&self) -> &LoadProfile {
        &self.profile
    }

    /// Resident-bytes of a component at a weights tag, from the manifest
    /// (ledger numbers must be known *before* loading).
    fn stored_bytes(&self, comp: &str, tag: &str) -> Result<usize> {
        let c = self.manifest.component(comp)?;
        c.weights
            .get(tag)
            .map(|w| w.bytes)
            .ok_or_else(|| Error::Manifest(format!("{comp}: no weights {tag}")))
    }

    /// Pin `(name, tag)` through the residency layer.  A miss loads
    /// via the shared artifact store (host half cached process-wide)
    /// and, when the warm tier holds this component's executable from
    /// a previous eviction, skips the compile — the warm reload path
    /// pays only the device upload.
    fn acquire_component(&mut self, name: &str, tag: &str) -> Result<ResidentComponent> {
        let bytes = self.stored_bytes(name, tag)?;
        let PipelinedExecutor { engine, manifest, residency, store, profile, .. } = self;
        // only a miss consumes the warm remnant; a resident hit must
        // not silently drop it
        let warm_exe = if residency.contains(name, tag) {
            None
        } else {
            residency.take_warm(name, tag)
        };
        residency.acquire(name, tag, bytes, || {
            let comp = manifest.component(name)?;
            let (host, hit) = store.get_or_load(manifest, comp, tag)?;
            let c = Component::load_from_host(engine, comp, &host, warm_exe, hit)?;
            profile.record(&c.stats);
            Ok(Rc::new(c))
        })
    }

    /// [`Self::acquire_component`] with an RAII pin: the returned
    /// guard balances the pin if the caller unwinds (error or panic)
    /// before its explicit release — continuous sessions hold their
    /// components through arbitrary user-request work, so their pins
    /// must survive any exit path (see `residency::PinGuard`).
    fn acquire_component_pinned(
        &mut self,
        name: &str,
        tag: &str,
    ) -> Result<(ResidentComponent, PinGuard)> {
        let bytes = self.stored_bytes(name, tag)?;
        let PipelinedExecutor { engine, manifest, residency, store, profile, .. } = self;
        let warm_exe = if residency.contains(name, tag) {
            None
        } else {
            residency.take_warm(name, tag)
        };
        residency.acquire_pinned(name, tag, bytes, || {
            let comp = manifest.component(name)?;
            let (host, hit) = store.get_or_load(manifest, comp, tag)?;
            let c = Component::load_from_host(engine, comp, &host, warm_exe, hit)?;
            profile.record(&c.stats);
            Ok(Rc::new(c))
        })
    }

    /// Warm the UNet cache (variant per options) without holding a pin.
    pub fn ensure_unet(&mut self, variant: &str) -> Result<()> {
        let name = format!("unet_{variant}");
        let tag = self.options.unet_weights.clone();
        self.acquire_component(&name, &tag)?;
        self.residency.release(&name, &tag, Retention::Cache)
    }

    /// Drop every component no request is using (e.g. between traffic
    /// bursts); returns the bytes freed.  Evicting the text encoder
    /// invalidates the derived uncond-context cache with it.
    pub fn evict_idle(&mut self) -> usize {
        self.uncond_ctx = None;
        self.residency.evict_idle()
    }

    /// Shed every reclaimable byte (memory-pressure ladder rung 2):
    /// clear the warm executable tier, then evict all idle resident
    /// components.  Pinned components survive.  Returns the resident
    /// bytes freed (warm entries are accounted outside the ledger).
    pub fn shed_memory(&mut self) -> usize {
        self.residency.clear_warm();
        self.evict_idle()
    }

    /// Rebase the executor's memory budget to the governor's learned
    /// effective budget (ladder rung 3: re-plan under pressure).  The
    /// ledger clamps to live allocations, so shrinking below residency
    /// only blocks new acquisitions until evictions catch up; the
    /// fail-fast feasibility checks use the new figure immediately.
    /// Returns the budget actually installed.
    pub fn rebase_budget(&mut self, bytes: usize) -> usize {
        let installed = self.residency.set_budget(bytes);
        self.options.memory_budget = installed;
        installed
    }

    /// The Fig. 4 occupancy trace.
    pub fn memory_trace(&self) -> &MemoryTrace {
        self.residency.trace()
    }

    /// Full text-to-image generation with the configured defaults.
    pub fn generate(
        &mut self,
        prompt: &str,
        seed: u64,
        variant: &str,
    ) -> Result<GenerateResult> {
        self.generate_with(prompt, seed, variant, &ExecOverrides::default())
    }

    /// Full text-to-image generation with per-request overrides — a
    /// micro-batch of one, so solo runs share the batched code path
    /// (and its numerics) exactly.
    pub fn generate_with(
        &mut self,
        prompt: &str,
        seed: u64,
        variant: &str,
        overrides: &ExecOverrides,
    ) -> Result<GenerateResult> {
        let req = BatchRequest {
            prompt: prompt.to_string(),
            seed,
            overrides: overrides.clone(),
        };
        self.generate_batch(std::slice::from_ref(&req), variant)
            .pop()
            .unwrap_or_else(|| Err(Error::Runtime("empty generation batch".into())))
    }

    /// Generate a micro-batch of requests.  Requests are grouped by
    /// compatibility (same UNet executable); each group shares one
    /// CFG-batched UNet dispatch per denoise step.  Results come back
    /// in submission order, one per request — a failed decode fails
    /// only its own request, a failed shared stage fails its group.
    pub fn generate_batch(
        &mut self,
        reqs: &[BatchRequest],
        default_variant: &str,
    ) -> Vec<Result<GenerateResult>> {
        let mut slots: Vec<Option<Result<GenerateResult>>> =
            reqs.iter().map(|_| None).collect();
        let groups = form_batches(
            reqs,
            default_variant,
            &self.options.unet_weights,
            self.options.sampler,
            reqs.len().max(1),
        );
        for g in &groups {
            // Legacy artifacts with a per-dispatch scalar timestep
            // cannot carry per-request schedules: fall back to solo.
            let batchable = crate::pipeline::batch::supports_microbatch(
                &self.manifest,
                &g.key.variant,
            );
            let runs: Vec<Vec<usize>> = if g.indices.len() > 1 && !batchable {
                g.indices.iter().map(|&i| vec![i]).collect()
            } else {
                vec![g.indices.clone()]
            };
            for idx_set in runs {
                match self.run_group(&g.key, reqs, &idx_set) {
                    Ok(results) => {
                        for (&slot, r) in idx_set.iter().zip(results) {
                            slots[slot] = Some(r);
                        }
                    }
                    Err(e) => {
                        for &slot in &idx_set {
                            slots[slot] = Some(Err(e.clone()));
                        }
                    }
                }
            }
        }
        slots
            .into_iter()
            .map(|s| {
                s.unwrap_or_else(|| Err(Error::Runtime("request not scheduled".into())))
            })
            .collect()
    }

    /// Planner-style feasibility: resident peak the stage sequence
    /// needs under `variant`/`tag` — UNet + max(text encoder, decoder)
    /// pipelined (paper Sec. 3.3), the sum of all three otherwise.
    /// Ledger numbers come from the manifest, so this is exactly the
    /// peak the residency layer would hit mid-generation.
    pub fn predicted_peak(&self, variant: &str, tag: &str) -> Result<usize> {
        let unet = self.stored_bytes(&format!("unet_{variant}"), tag)?;
        let text = self.stored_bytes("text_encoder", AUX_TAG)?;
        let dec = self.stored_bytes("decoder", AUX_TAG)?;
        Ok(if self.options.pipelined {
            unet.saturating_add(text.max(dec))
        } else {
            unet.saturating_add(text).saturating_add(dec)
        })
    }

    /// Run one compatible group end-to-end.  Outer `Err` = a shared
    /// stage failed (whole group); inner per-member results cover the
    /// decode stage.
    fn run_group(
        &mut self,
        key: &BatchKey,
        reqs: &[BatchRequest],
        indices: &[usize],
    ) -> Result<Vec<Result<GenerateResult>>> {
        let t_start = Instant::now();
        let mut tm = StageTimings::default();
        let profile_before = self.profile.clone();

        // fail fast with the plan-predicted peak instead of burning
        // encode + denoise work only to hit the ledger at the decoder
        // reserve (the budget cannot be met by any eviction order)
        if self.options.memory_budget != usize::MAX {
            let needed = self.predicted_peak(&key.variant, &key.weights_tag)?;
            if needed > self.options.memory_budget {
                return Err(Error::Pipeline(format!(
                    "infeasible under memory budget: stage sequence needs {:.1} MB \
                     resident ({} variant, {} weights, pipelined={}), budget is {:.1} MB",
                    needed as f64 / 1e6,
                    key.variant,
                    key.weights_tag,
                    self.options.pipelined,
                    self.options.memory_budget as f64 / 1e6,
                )));
            }
        }

        // ---- UNet resident (cached across requests) --------------------
        let unet_name = format!("unet_{}", key.variant);
        let t0 = Instant::now();
        let unet = self.acquire_component(&unet_name, &key.weights_tag)?;
        tm.unet_load_s = t0.elapsed().as_secs_f64();

        let result = self.run_group_stages(key, reqs, indices, unet, &mut tm);
        if result.is_err() {
            // a failed group must not leak pins into the next one; the
            // purged encoder takes its cached uncond context with it
            self.residency.purge("text_encoder", AUX_TAG);
            self.residency.purge("decoder", AUX_TAG);
            self.uncond_ctx = None;
        }
        // unpin the UNet but keep it cached — the paper's app behaviour
        let _ = self.residency.release(&unet_name, &key.weights_tag, Retention::Cache);

        // max_steps comes from the member schedules (not the surviving
        // outputs): the denoise wall covers max_steps dispatches, and a
        // member that participated in only `steps` of them is charged
        // its share so the per-step stage metric stays truthful for
        // stragglers even when another member's decode failed
        let (stages, max_steps) = result?;
        tm.total_s = t_start.elapsed().as_secs_f64();
        let image_size = self.manifest.image_size;
        let peak = self.residency.peak();
        // the group's load work (shared across the batch) is amortized
        // over the surviving members: timed stages split evenly, load
        // counters whole on the first survivor (see LoadProfile::share)
        let load_delta = self.profile.since(&profile_before);
        let n_ok = stages.iter().filter(|s| s.is_ok()).count().max(1);
        // the batch's non-denoise wall, split evenly for busy shares
        let overhead_share = (tm.total_s - tm.denoise_s).max(0.0) / n_ok as f64;
        let mut first_ok = true;
        Ok(stages
            .into_iter()
            .map(|s| {
                s.map(|so| {
                    let mut t = tm.clone();
                    t.loads = load_delta.share(n_ok, std::mem::take(&mut first_ok));
                    t.denoise_steps = so.steps;
                    t.busy_share_s = overhead_share + so.busy_denoise_s;
                    if max_steps > 0 {
                        t.denoise_s = tm.denoise_s * so.steps as f64 / max_steps as f64;
                    }
                    GenerateResult {
                        image: so.image,
                        image_size,
                        latent: so.latent,
                        timings: t,
                        peak_memory: peak,
                    }
                })
            })
            .collect())
    }

    /// Everything between UNet acquisition and the final images: text
    /// encode, batched denoise with decoder prefetch overlap, decode.
    /// Returns the per-member stage outputs plus the number of denoise
    /// dispatches the batch ran (`max_steps` over member schedules).
    fn run_group_stages(
        &mut self,
        key: &BatchKey,
        reqs: &[BatchRequest],
        indices: &[usize],
        unet: ResidentComponent,
        tm: &mut StageTimings,
    ) -> Result<(Vec<Result<StageOutput>>, usize)> {
        // [text, unet, decode] work signatures for this variant, when a
        // calibration sink is installed and the planner priced the pair
        let sigs: Option<[StageSig; 3]> = self
            .observer
            .as_ref()
            .and_then(|o| o.sigs.get(&key.variant).copied());
        // ---- non-pipelined baseline: everything resident up front ------
        let decoder_bytes = self.stored_bytes("decoder", AUX_TAG)?;
        let decoder_manifest = self.manifest.component("decoder")?.clone();
        let mut decoder: Option<ResidentComponent> = None;
        if !self.options.pipelined {
            let t0 = Instant::now();
            decoder = Some(self.acquire_component("decoder", AUX_TAG)?);
            tm.decoder_load_s = t0.elapsed().as_secs_f64();
        }

        // ---- text encode (acquire -> encode -> evict) ------------------
        let t0 = Instant::now();
        let text = self.acquire_component("text_encoder", AUX_TAG)?;
        tm.text_load_s = t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let seq = self.manifest.tokenizer.seq_len;
        let vocab = self.manifest.tokenizer.vocab_size;
        // the uncond ("") context depends only on the encoder weights:
        // one dispatch the first time, a cache hit for every request
        // after — each generation costs one encoder dispatch, not two
        let mut enc_dispatches = indices.len();
        let uncond = match self.uncond_ctx.clone() {
            Some(c) => c,
            None => {
                enc_dispatches += 1;
                let ids = tokenizer::encode("", vocab, seq);
                let out = text.run(&self.engine, &[ActInput::i32(ids)])?;
                let rc = Rc::new(out.into_iter().next().unwrap_or_default());
                self.uncond_ctx = Some(Rc::clone(&rc));
                rc
            }
        };

        let s = self.manifest.latent_size;
        let c = self.manifest.latent_channels;
        let n_latent = s * s * c;
        let mut members: Vec<Member> = Vec::with_capacity(indices.len());
        for &slot in indices {
            let r = &reqs[slot];
            let num_steps = r.overrides.num_steps.unwrap_or(self.options.num_steps);
            let guidance = r
                .overrides
                .guidance_scale
                .unwrap_or(self.options.guidance_scale);
            let ids = tokenizer::encode(&r.prompt, vocab, seq);
            let cond = text
                .run(&self.engine, &[ActInput::i32(ids)])?
                .into_iter()
                .next()
                .unwrap_or_default();
            let mut rng = Rng::new(r.seed);
            members.push(Member {
                sampler: key.sampler,
                ts: key.sampler.schedule(&self.ddim, num_steps),
                guidance,
                latent: rng.normal_f32_vec(n_latent),
                eps: vec![0f32; n_latent],
                cond,
                history: Vec::new(),
            });
        }
        tm.text_encode_s = t0.elapsed().as_secs_f64();
        if let (Some(o), Some(s)) = (&self.observer, &sigs) {
            // per-dispatch wall: the encode stage ran enc_dispatches
            // equal forward passes
            o.observe(&s[0], 1, tm.text_encode_s / enc_dispatches.max(1) as f64);
        }

        drop(text);
        self.residency.release("text_encoder", AUX_TAG, Retention::Evict)?;
        self.residency.mark("text-encoder-evicted");

        // ---- batched denoise loop with decoder prefetch overlap --------
        let mut prefetch = if self.options.pipelined {
            Some(Prefetcher::spawn(
                &self.store,
                &self.manifest,
                &decoder_manifest,
                AUX_TAG,
            )?)
        } else {
            None // baseline: decoder already resident
        };
        let mut prefetch_charged = false;

        let t0 = Instant::now();
        let PipelinedExecutor { engine, residency, ddim, profile, observer, .. } = self;

        let mut sb = StepBuffers::for_unet(&unet, members.len())?;
        let max_steps = members.iter().map(|m| m.ts.len()).max().unwrap_or(0);
        let mut ctx_host: Vec<f32> = Vec::with_capacity(members.len() * 2 * uncond.len());
        // force a repack (context upload + fresh step buffers) on entry
        // and whenever a member's schedule ends and the batch shrinks
        let mut live_count = usize::MAX;
        // per-member time-weighted denoise shares (busy accounting)
        let mut busy: Vec<f64> = vec![0.0; members.len()];
        for step in 0..max_steps {
            let t_step = Instant::now();
            let n_live = members.iter().filter(|m| m.ts.len() > step).count();
            if n_live != live_count {
                live_count = n_live;
                ctx_host.clear();
                for m in members.iter().filter(|m| m.ts.len() > step) {
                    // context rows per request: uncond then cond,
                    // matching the solo CFG layout
                    ctx_host.extend_from_slice(&uncond);
                    ctx_host.extend_from_slice(&m.cond);
                }
                sb.repack(engine, &unet, &ctx_host, n_live)?;
            }
            for (k, m) in members.iter().filter(|m| m.ts.len() > step).enumerate() {
                sb.pack(k, &m.latent, m.ts[step] as f32);
            }
            // one CFG-batched UNet dispatch for the whole live batch
            let t_disp = Instant::now();
            sb.dispatch(engine, &unet)?;
            if let (Some(o), Some(s)) = (observer.as_ref(), &sigs) {
                o.observe(&s[1], n_live, t_disp.elapsed().as_secs_f64());
            }

            let n = sb.row_elems();
            let eps2 = &sb.out[0];
            for (k, m) in members
                .iter_mut()
                .filter(|m| m.ts.len() > step)
                .enumerate()
            {
                let base = 2 * k * n;
                guide(
                    &eps2[base..base + n],
                    &eps2[base + n..base + 2 * n],
                    m.guidance,
                    &mut m.eps,
                );
                m.advance(ddim, step);
            }
            let share = t_step.elapsed().as_secs_f64() / n_live.max(1) as f64;
            for (i, m) in members.iter().enumerate() {
                if m.ts.len() > step {
                    busy[i] += share;
                }
            }

            // charge the decoder prefetch as soon as its bytes land
            if let Some(p) = prefetch.as_mut() {
                if !prefetch_charged && p.poll() {
                    residency.reserve("decoder", AUX_TAG, decoder_bytes)?;
                    residency.mark(&format!("decoder-prefetched@step{step}"));
                    prefetch_charged = true;
                }
            }
        }
        tm.denoise_s = t0.elapsed().as_secs_f64();
        residency.mark("denoise-done");

        // ---- decode -----------------------------------------------------
        if let Some(p) = prefetch.take() {
            let t0 = Instant::now();
            let pf = p.join()?;
            if !prefetch_charged {
                residency.reserve("decoder", AUX_TAG, decoder_bytes)?;
            }
            // warm reload: reuse the decoder executable kept across the
            // previous eviction, paying only the device upload
            let warm_exe = residency.take_warm("decoder", AUX_TAG);
            let loaded = Component::load_from_host(
                engine,
                &decoder_manifest,
                &pf.host,
                warm_exe,
                pf.store_hit,
            );
            match loaded {
                Ok(c) => {
                    profile.record(&c.stats);
                    decoder = Some(residency.fulfill("decoder", AUX_TAG, Rc::new(c))?);
                }
                Err(e) => {
                    let _ = residency.cancel("decoder", AUX_TAG);
                    return Err(e);
                }
            }
            tm.decoder_load_s = t0.elapsed().as_secs_f64();
        }
        let dec = decoder.expect("decoder loaded");
        let t0 = Instant::now();
        let mut outputs: Vec<Result<StageOutput>> = Vec::with_capacity(members.len());
        for (i, m) in members.into_iter().enumerate() {
            let t_dec = Instant::now();
            let img = dec.run(engine, &[ActInput::F32(m.latent.clone())]);
            if let (Some(o), Some(s)) = (observer.as_ref(), &sigs) {
                o.observe(&s[2], 1, t_dec.elapsed().as_secs_f64());
            }
            match img {
                Ok(out) => outputs.push(Ok(StageOutput {
                    image: out.into_iter().next().unwrap_or_default(),
                    latent: m.latent,
                    steps: m.ts.len(),
                    busy_denoise_s: busy[i],
                })),
                Err(e) => outputs.push(Err(e)),
            }
        }
        tm.decode_s = t0.elapsed().as_secs_f64();
        drop(dec);
        residency.release("decoder", AUX_TAG, Retention::Evict)?;
        residency.mark("decoder-evicted");

        Ok((outputs, max_steps))
    }

    /// Run one *continuous* session: start with `initial` rows and,
    /// at every denoise-step boundary, let the `control` splice in
    /// compatible joiners (each starting at its own schedule head),
    /// retire rows whose schedule ended (decoded immediately — their
    /// slots are reclaimed, the straggler tail never runs alone just
    /// because it popped that way), and checkpoint/requeue preemption
    /// victims.  Outcomes are delivered through
    /// [`ContinuousControl::complete`], not returned: rows finish at
    /// different times and the caller may be feeding the session long
    /// after the first completion.
    ///
    /// Numerics are the batched (= solo) ones: a row's result is
    /// bit-identical to [`Self::generate_with`] with the same seed,
    /// regardless of when it joined, who its batchmates were, or how
    /// often it was preempted and resumed.
    ///
    /// An `Err` is a shared-stage failure: rows not yet completed were
    /// neither decoded nor requeued, and the caller must fail them.
    pub fn run_continuous(
        &mut self,
        key: &BatchKey,
        default_variant: &str,
        initial: Vec<ContinuousJob>,
        max_batch: usize,
        control: &mut dyn ContinuousControl,
    ) -> Result<SessionStats> {
        // fail fast on an infeasible budget, as run_group does
        if self.options.memory_budget != usize::MAX {
            let needed = self.predicted_peak(&key.variant, &key.weights_tag)?;
            if needed > self.options.memory_budget {
                return Err(Error::Pipeline(format!(
                    "infeasible under memory budget: stage sequence needs {:.1} MB \
                     resident ({} variant, {} weights, pipelined={}), budget is {:.1} MB",
                    needed as f64 / 1e6,
                    key.variant,
                    key.weights_tag,
                    self.options.pipelined,
                    self.options.memory_budget as f64 / 1e6,
                )));
            }
        }
        // legacy scalar-timestep artifacts cannot carry per-row
        // schedules: run rows one at a time instead of refusing service
        let cap = if crate::pipeline::batch::supports_microbatch(&self.manifest, &key.variant)
        {
            max_batch.max(1)
        } else {
            1
        };
        let unet_name = format!("unet_{}", key.variant);
        // RAII pin: a panic unwinding through the session balances the
        // UNet pin, so a restarted worker's residency never wedges
        let (unet, unet_pin) = self.acquire_component_pinned(&unet_name, &key.weights_tag)?;
        let result = self.continuous_session(key, default_variant, &unet, initial, cap, control);
        if result.is_err() {
            // a failed session must not leak pins into the next one
            self.residency.purge("text_encoder", AUX_TAG);
            self.residency.purge("decoder", AUX_TAG);
            self.uncond_ctx = None;
        }
        drop(unet);
        unet_pin.disarm();
        let _ = self.residency.release(&unet_name, &key.weights_tag, Retention::Cache);
        result
    }

    /// The session loop between UNet acquisition and drain: admit →
    /// retire → recompose → dispatch → account → retire → preempt →
    /// poll, until no row is live and the control has no joiners.
    fn continuous_session(
        &mut self,
        key: &BatchKey,
        default_variant: &str,
        unet: &ResidentComponent,
        initial: Vec<ContinuousJob>,
        cap: usize,
        control: &mut dyn ContinuousControl,
    ) -> Result<SessionStats> {
        let sigs: Option<[StageSig; 3]> = self
            .observer
            .as_ref()
            .and_then(|o| o.sigs.get(&key.variant).copied());
        let mut stats = SessionStats::default();
        let mut sb = StepBuffers::for_unet(unet, cap)?;
        let mut live: Vec<LiveMember> = Vec::new();
        let mut pending = initial;
        // rolling load anchor: deltas are charged (amortized) to the
        // rows completed at each flush
        let mut anchor = self.profile.clone();
        let mut ctx_host: Vec<f32> = Vec::new();
        // composition changed since the last repack (join/leave/preempt)
        let mut dirty = true;

        loop {
            // admit at most the free seats; the remainder stays pending
            // for the next boundary (cap can be 1 on legacy artifacts
            // even when the pop handed us more)
            if !pending.is_empty() && live.len() < cap {
                let take = (cap - live.len()).min(pending.len());
                let wave: Vec<ContinuousJob> = pending.drain(..take).collect();
                let before = live.len();
                self.admit_continuous(wave, key, default_variant, &mut live, &mut stats, control)?;
                dirty |= live.len() != before;
            }
            // a checkpoint resumed past its schedule end has nothing
            // left to denoise: retire it before packing would index
            // beyond the schedule
            self.retire_finished(&mut live, &mut anchor, &mut dirty, &mut stats, sigs, control)?;

            if live.is_empty() {
                if pending.is_empty() {
                    pending = control.poll_joins(key, cap);
                }
                if pending.is_empty() {
                    break;
                }
                continue;
            }

            if dirty {
                let uncond = self
                    .uncond_ctx
                    .clone()
                    .ok_or_else(|| Error::Runtime("uncond context missing".into()))?;
                ctx_host.clear();
                for lm in &live {
                    // context rows per request: uncond then cond,
                    // matching the solo CFG layout
                    ctx_host.extend_from_slice(&uncond);
                    ctx_host.extend_from_slice(&lm.m.cond);
                }
                sb.repack(&self.engine, unet, &ctx_host, live.len())?;
                dirty = false;
            }

            let t_step = Instant::now();
            for (k, lm) in live.iter().enumerate() {
                sb.pack(k, &lm.m.latent, lm.m.ts[lm.pos] as f32);
            }
            {
                // one CFG-batched UNet dispatch for every live row
                let PipelinedExecutor { engine, ddim, observer, .. } = self;
                let t_disp = Instant::now();
                if let Err(e) = sb.dispatch(engine, unet) {
                    if !e.is_transient() && !e.is_oom() {
                        return Err(e);
                    }
                    // transient fault or OOM: the faulted step was never
                    // applied, so every live row's state is exactly its
                    // last good step.  Checkpoint them all out for
                    // bounded retry (resuming is bit-identical to an
                    // uninterrupted run).
                    for lm in live.drain(..) {
                        let LiveMember { token, req, m, pos, busy_s, denoise_s, .. } = lm;
                        control.retry(
                            ContinuousJob {
                                req,
                                token,
                                resume: Some(Checkpoint {
                                    ts: m.ts,
                                    pos,
                                    latent: m.latent,
                                    guidance: m.guidance,
                                    cond: m.cond,
                                    history: m.history,
                                    busy_s,
                                    denoise_s,
                                }),
                            },
                            &e,
                        );
                    }
                    if e.is_oom() {
                        // An exhausted allocator will not recover by
                        // re-dispatching the same batch: surface the OOM
                        // so the worker degrades (pressure ladder) before
                        // a fresh session resumes the checkpointed rows.
                        return Err(e);
                    }
                    // transient: keep the session alive and retry here.
                    dirty = true;
                    continue;
                }
                if let (Some(o), Some(s)) = (observer.as_ref(), &sigs) {
                    o.observe(&s[1], live.len(), t_disp.elapsed().as_secs_f64());
                }
                let n = sb.row_elems();
                let eps2 = &sb.out[0];
                for (k, lm) in live.iter_mut().enumerate() {
                    let base = 2 * k * n;
                    let m = &mut lm.m;
                    guide(
                        &eps2[base..base + n],
                        &eps2[base + n..base + 2 * n],
                        m.guidance,
                        &mut m.eps,
                    );
                    let pos = lm.pos;
                    m.advance(ddim, pos);
                    lm.pos += 1;
                }
            }
            let wall = t_step.elapsed().as_secs_f64();
            stats.steps += 1;
            let n_live = live.len();
            for lm in &mut live {
                lm.busy_s += wall / n_live as f64;
                lm.denoise_s += wall;
            }
            control.on_step(n_live, wall);

            // reclaim finished rows' slots before the boundary decisions
            self.retire_finished(&mut live, &mut anchor, &mut dirty, &mut stats, sigs, control)?;

            // preemption: the control names victims (typically when the
            // queue head's deadline is infeasible and no slot is free)
            let rows: Vec<LiveRow> = live
                .iter()
                .map(|lm| LiveRow {
                    token: lm.token,
                    steps_remaining: lm.m.ts.len() - lm.pos,
                })
                .collect();
            for token in control.preempt_victims(&rows, cap.saturating_sub(live.len())) {
                let Some(at) = live.iter().position(|lm| lm.token == token) else {
                    continue; // already retired or unknown: ignore
                };
                let LiveMember { token, req, m, pos, busy_s, denoise_s, .. } = live.remove(at);
                stats.preemptions += 1;
                dirty = true;
                control.requeue(ContinuousJob {
                    req,
                    token,
                    resume: Some(Checkpoint {
                        ts: m.ts,
                        pos,
                        latent: m.latent,
                        guidance: m.guidance,
                        cond: m.cond,
                        history: m.history,
                        busy_s,
                        denoise_s,
                    }),
                });
            }

            // refill freed seats at this boundary (leftover pending
            // jobs keep their place ahead of fresh joiners)
            let free = cap.saturating_sub(live.len());
            if free > pending.len() {
                let more = control.poll_joins(key, free - pending.len());
                pending.extend(more);
            }
        }
        Ok(stats)
    }

    /// Admit jobs into the live set: fresh rows are encoded (one
    /// encoder acquire per admission wave, evicted after), resumed
    /// rows are rebuilt from their checkpoints without touching the
    /// encoder.  Jobs that resolve to a different executable than the
    /// session's are bounced back untouched — reclaimed slots never
    /// mix rows across [`BatchKey`]s.
    fn admit_continuous(
        &mut self,
        jobs: Vec<ContinuousJob>,
        key: &BatchKey,
        default_variant: &str,
        live: &mut Vec<LiveMember>,
        stats: &mut SessionStats,
        control: &mut dyn ContinuousControl,
    ) -> Result<()> {
        let mut accepted: Vec<ContinuousJob> = Vec::with_capacity(jobs.len());
        for job in jobs {
            let variant = job.req.overrides.variant.as_deref().unwrap_or(default_variant);
            let sampler = job.req.overrides.sampler.unwrap_or(self.options.sampler);
            if variant != key.variant
                || self.options.unet_weights != key.weights_tag
                || sampler != key.sampler
            {
                control.requeue(job);
                continue;
            }
            accepted.push(job);
        }
        if accepted.is_empty() {
            return Ok(());
        }
        let joined = stats.steps > 0;
        // the encoder is needed for any fresh prompt, and for the
        // uncond context when no earlier request cached it
        let need_encoder =
            self.uncond_ctx.is_none() || accepted.iter().any(|j| j.resume.is_none());
        let (text, text_pin) = if need_encoder {
            let (c, pin) = self.acquire_component_pinned("text_encoder", AUX_TAG)?;
            (Some(c), Some(pin))
        } else {
            (None, None)
        };
        let t0 = Instant::now();
        let seq = self.manifest.tokenizer.seq_len;
        let vocab = self.manifest.tokenizer.vocab_size;
        let mut enc_dispatches = accepted.iter().filter(|j| j.resume.is_none()).count();
        if self.uncond_ctx.is_none() {
            enc_dispatches += 1;
            let enc = text.as_ref().expect("encoder acquired for uncond");
            let ids = tokenizer::encode("", vocab, seq);
            let out = enc.run(&self.engine, &[ActInput::i32(ids)])?;
            self.uncond_ctx = Some(Rc::new(out.into_iter().next().unwrap_or_default()));
        }
        let s = self.manifest.latent_size;
        let c = self.manifest.latent_channels;
        let n_latent = s * s * c;
        let n_admitted = accepted.len();
        for job in accepted {
            let ContinuousJob { req, token, resume } = job;
            let (m, pos, busy_s, denoise_s) = match resume {
                Some(cp) => {
                    stats.resumes += 1;
                    // solver state (the eps history) is restored from
                    // the checkpoint, never recomputed — resuming a
                    // multistep row mid-schedule is bit-identical to
                    // its uninterrupted run
                    let m = Member {
                        sampler: key.sampler,
                        ts: cp.ts,
                        guidance: cp.guidance,
                        latent: cp.latent,
                        eps: vec![0f32; n_latent],
                        cond: cp.cond,
                        history: cp.history,
                    };
                    (m, cp.pos, cp.busy_s, cp.denoise_s)
                }
                None => {
                    let enc = text.as_ref().expect("encoder acquired for fresh rows");
                    let num_steps =
                        req.overrides.num_steps.unwrap_or(self.options.num_steps);
                    let guidance = req
                        .overrides
                        .guidance_scale
                        .unwrap_or(self.options.guidance_scale);
                    let ids = tokenizer::encode(&req.prompt, vocab, seq);
                    let cond = enc
                        .run(&self.engine, &[ActInput::i32(ids)])?
                        .into_iter()
                        .next()
                        .unwrap_or_default();
                    let mut rng = Rng::new(req.seed);
                    let m = Member {
                        sampler: key.sampler,
                        ts: key.sampler.schedule(&self.ddim, num_steps),
                        guidance,
                        latent: rng.normal_f32_vec(n_latent),
                        eps: vec![0f32; n_latent],
                        cond,
                        history: Vec::new(),
                    };
                    (m, 0, 0.0, 0.0)
                }
            };
            if joined {
                stats.joins += 1;
            }
            live.push(LiveMember {
                token,
                req,
                m,
                pos,
                busy_s,
                denoise_s,
                start: Instant::now(),
            });
        }
        let enc_wall = t0.elapsed().as_secs_f64();
        if enc_dispatches > 0 {
            if let Some(o) = &self.observer {
                if let Some(s) = o.sigs.get(&key.variant) {
                    o.observe(&s[0], 1, enc_wall / enc_dispatches as f64);
                }
            }
        }
        // the admission wave's encode wall, split across its rows
        let enc_share = enc_wall / n_admitted as f64;
        for lm in live.iter_mut().rev().take(n_admitted) {
            lm.busy_s += enc_share;
        }
        if let Some(pin) = text_pin {
            drop(text);
            pin.disarm();
            self.residency.release("text_encoder", AUX_TAG, Retention::Evict)?;
            self.residency.mark("text-encoder-evicted");
        }
        stats.peak_occupancy = stats.peak_occupancy.max(live.len());
        Ok(())
    }

    /// Remove rows whose schedule ended and flush them through the
    /// decoder.  A leave is only counted when batchmates stay live —
    /// the last rows out are just the session ending.
    fn retire_finished(
        &mut self,
        live: &mut Vec<LiveMember>,
        anchor: &mut LoadProfile,
        dirty: &mut bool,
        stats: &mut SessionStats,
        sigs: Option<[StageSig; 3]>,
        control: &mut dyn ContinuousControl,
    ) -> Result<()> {
        let mut finished: Vec<LiveMember> = Vec::new();
        let mut i = 0;
        while i < live.len() {
            if live[i].pos >= live[i].m.ts.len() {
                finished.push(live.remove(i));
            } else {
                i += 1;
            }
        }
        if finished.is_empty() {
            return Ok(());
        }
        if !live.is_empty() {
            stats.leaves += finished.len();
        }
        *dirty = true;
        self.flush_continuous(finished, anchor, stats, sigs, control)
    }

    /// Decode and complete a wave of finished rows: decoder acquired
    /// (warm tier makes the repeat acquires upload-only), each row
    /// decoded and delivered, decoder evicted again.  The session's
    /// load delta since the last flush is amortized over the wave.
    fn flush_continuous(
        &mut self,
        finished: Vec<LiveMember>,
        anchor: &mut LoadProfile,
        stats: &mut SessionStats,
        sigs: Option<[StageSig; 3]>,
        control: &mut dyn ContinuousControl,
    ) -> Result<()> {
        let t0 = Instant::now();
        let (dec, dec_pin) = match self.acquire_component_pinned("decoder", AUX_TAG) {
            Ok(d) => d,
            Err(e) => {
                // decoder never came up: these rows are lost either way,
                // deliver the failure before surfacing it
                for lm in finished {
                    control.complete(lm.token, Err(e.clone()));
                    stats.completed += 1;
                }
                return Err(e);
            }
        };
        let dec_load_s = t0.elapsed().as_secs_f64();
        let load_delta = self.profile.since(anchor);
        let n = finished.len();
        let image_size = self.manifest.image_size;
        let peak = self.residency.peak();
        let mut first_ok = true;
        for lm in finished {
            let token = lm.token;
            let t_dec = Instant::now();
            let img = dec.run(&self.engine, &[ActInput::F32(lm.m.latent.clone())]);
            let decode_s = t_dec.elapsed().as_secs_f64();
            if let (Some(o), Some(s)) = (&self.observer, &sigs) {
                o.observe(&s[2], 1, decode_s);
            }
            let result = img.map(|out| {
                let t = StageTimings {
                    denoise_steps: lm.m.ts.len(),
                    denoise_s: lm.denoise_s,
                    decode_s,
                    decoder_load_s: dec_load_s / n as f64,
                    busy_share_s: lm.busy_s + decode_s + dec_load_s / n as f64,
                    total_s: lm.start.elapsed().as_secs_f64(),
                    loads: load_delta.share(n, std::mem::take(&mut first_ok)),
                    ..Default::default()
                };
                GenerateResult {
                    image: out.into_iter().next().unwrap_or_default(),
                    image_size,
                    latent: lm.m.latent,
                    timings: t,
                    peak_memory: peak,
                }
            });
            control.complete(token, result);
            stats.completed += 1;
        }
        *anchor = self.profile.clone();
        drop(dec);
        dec_pin.disarm();
        self.residency.release("decoder", AUX_TAG, Retention::Evict)?;
        self.residency.mark("decoder-evicted");
        Ok(())
    }
}
