//! Memory ledger — the accounting substrate for the paper's Sec. 3.3
//! pipelined execution.
//!
//! A device memory budget (the phone's per-process limit) with named
//! allocations per component.  Every alloc/free is appended to a trace
//! (crate::pipeline::trace) so a run reproduces the paper's Fig. 4
//! occupancy chart.  Exceeding the budget is an error — the condition
//! pipelining exists to avoid.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

use super::trace::{MemoryTrace, TraceEvent};

#[derive(Debug)]
pub struct MemoryLedger {
    pub budget: usize,
    allocations: BTreeMap<String, usize>,
    used: usize,
    peak: usize,
    pub trace: MemoryTrace,
}

impl MemoryLedger {
    pub fn new(budget: usize) -> MemoryLedger {
        MemoryLedger {
            budget,
            allocations: BTreeMap::new(),
            used: 0,
            peak: 0,
            trace: MemoryTrace::new(),
        }
    }

    /// Unlimited ledger (baseline, non-pipelined accounting).
    pub fn unbounded() -> MemoryLedger {
        Self::new(usize::MAX)
    }

    pub fn alloc(&mut self, name: &str, bytes: usize) -> Result<()> {
        if self.allocations.contains_key(name) {
            return Err(Error::Pipeline(format!("{name} already allocated")));
        }
        if self.used + bytes > self.budget {
            return Err(Error::Pipeline(format!(
                "memory budget exceeded: {} + {} > {} (components: {:?})",
                self.used, bytes, self.budget, self.allocations
            )));
        }
        self.allocations.insert(name.to_string(), bytes);
        self.used += bytes;
        self.peak = self.peak.max(self.used);
        self.trace.push(TraceEvent::alloc(name, bytes, self.used));
        Ok(())
    }

    pub fn free(&mut self, name: &str) -> Result<usize> {
        let bytes = self
            .allocations
            .remove(name)
            .ok_or_else(|| Error::Pipeline(format!("{name} not allocated")))?;
        self.used -= bytes;
        self.trace.push(TraceEvent::free(name, bytes, self.used));
        Ok(bytes)
    }

    pub fn mark(&mut self, label: &str) {
        self.trace.push(TraceEvent::mark(label, self.used));
    }

    /// Bytes still allocatable before the budget is hit.
    pub fn headroom(&self) -> usize {
        self.budget.saturating_sub(self.used)
    }

    /// Rebase the budget (memory-pressure governor shrinking a class's
    /// effective budget, or re-probing it back up).  Live allocations
    /// are never invalidated: the budget is clamped to at least the
    /// current `used`, so `used <= budget` holds across the change —
    /// shrinking below residency only blocks *new* allocations until
    /// evictions catch up.  Returns the budget actually installed.
    pub fn set_budget(&mut self, bytes: usize) -> usize {
        self.budget = bytes.max(self.used);
        self.mark("budget-rebased");
        self.budget
    }

    pub fn used(&self) -> usize {
        self.used
    }
    pub fn peak(&self) -> usize {
        self.peak
    }
    pub fn holds(&self, name: &str) -> bool {
        self.allocations.contains_key(name)
    }
    pub fn components(&self) -> &BTreeMap<String, usize> {
        &self.allocations
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let mut m = MemoryLedger::new(1000);
        m.alloc("unet", 600).unwrap();
        m.alloc("text", 300).unwrap();
        assert_eq!(m.used(), 900);
        assert!(m.alloc("decoder", 200).is_err(), "budget exceeded");
        m.free("text").unwrap();
        m.alloc("decoder", 200).unwrap();
        assert_eq!(m.used(), 800);
        assert_eq!(m.peak(), 900);
    }

    #[test]
    fn double_alloc_and_unknown_free_rejected() {
        let mut m = MemoryLedger::new(1000);
        m.alloc("a", 10).unwrap();
        assert!(m.alloc("a", 10).is_err());
        assert!(m.free("b").is_err());
    }

    #[test]
    fn budget_exact_alloc_is_allowed() {
        let mut m = MemoryLedger::new(1000);
        m.alloc("unet", 600).unwrap();
        m.alloc("rest", 400).unwrap();
        assert_eq!(m.used(), 1000, "allocations up to the exact budget fit");
        assert!(m.alloc("straw", 1).is_err(), "one byte over is rejected");
        m.free("rest").unwrap();
        m.alloc("rest2", 400).unwrap();
        assert_eq!(m.peak(), 1000);
    }

    #[test]
    fn zero_byte_alloc_and_free_balance() {
        let mut m = MemoryLedger::new(10);
        m.alloc("marker", 0).unwrap();
        assert_eq!(m.used(), 0);
        assert!(m.holds("marker"));
        assert_eq!(m.free("marker").unwrap(), 0);
        assert!(!m.holds("marker"));
    }

    #[test]
    fn trace_records_events() {
        let mut m = MemoryLedger::new(1000);
        m.alloc("unet", 500).unwrap();
        m.mark("denoise-start");
        m.free("unet").unwrap();
        assert_eq!(m.trace.events.len(), 3);
        assert_eq!(m.trace.events[1].total, 500);
        assert_eq!(m.trace.events[2].total, 0);
    }

    #[test]
    fn headroom_tracks_budget_minus_used() {
        let mut m = MemoryLedger::new(1000);
        assert_eq!(m.headroom(), 1000);
        m.alloc("unet", 600).unwrap();
        assert_eq!(m.headroom(), 400);
        assert_eq!(MemoryLedger::unbounded().headroom(), usize::MAX);
    }

    #[test]
    fn set_budget_clamps_to_live_allocations() {
        let mut m = MemoryLedger::new(1000);
        m.alloc("unet", 600).unwrap();
        // shrink below residency: clamped, new allocs blocked
        assert_eq!(m.set_budget(100), 600);
        assert_eq!(m.headroom(), 0);
        assert!(m.alloc("text", 1).is_err());
        // eviction restores headroom under the reduced budget
        m.free("unet").unwrap();
        assert_eq!(m.set_budget(100), 100);
        m.alloc("small", 100).unwrap();
        // re-probe upward
        assert_eq!(m.set_budget(1000), 1000);
        m.alloc("text", 300).unwrap();
        assert_eq!(m.used(), 400);
    }

    #[test]
    fn property_used_equals_sum_and_never_exceeds_budget() {
        crate::util::miniprop::forall("ledger invariants", 100, |g| {
            let budget = g.usize_in(100, 10_000);
            let mut m = MemoryLedger::new(budget);
            let mut live: Vec<(String, usize)> = Vec::new();
            for i in 0..g.usize_in(1, 30) {
                if g.bool() || live.is_empty() {
                    let sz = g.usize_in(1, 2000);
                    let name = format!("c{i}");
                    if m.alloc(&name, sz).is_ok() {
                        live.push((name, sz));
                    }
                } else {
                    let idx = g.usize_in(0, live.len() - 1);
                    let (name, _) = live.remove(idx);
                    m.free(&name).unwrap();
                }
                let sum: usize = live.iter().map(|(_, s)| s).sum();
                assert_eq!(m.used(), sum);
                assert!(m.used() <= budget);
                assert!(m.peak() >= m.used());
            }
        });
    }

    #[test]
    fn property_invariants_hold_across_interleaved_set_budget() {
        crate::util::miniprop::forall("ledger budget rebase invariants", 100, |g| {
            let mut m = MemoryLedger::new(g.usize_in(100, 10_000));
            let mut live: Vec<(String, usize)> = Vec::new();
            let mut last_peak = 0usize;
            for i in 0..g.usize_in(1, 40) {
                match g.usize_in(0, 3) {
                    0 | 1 => {
                        let sz = g.usize_in(1, 2000);
                        let name = format!("c{i}");
                        if m.alloc(&name, sz).is_ok() {
                            live.push((name, sz));
                        }
                    }
                    2 if !live.is_empty() => {
                        let idx = g.usize_in(0, live.len() - 1);
                        let (name, _) = live.remove(idx);
                        m.free(&name).unwrap();
                    }
                    _ => {
                        // governor-style rebase, shrinking or probing up
                        m.set_budget(g.usize_in(50, 12_000));
                    }
                }
                let sum: usize = live.iter().map(|(_, s)| s).sum();
                assert_eq!(m.used(), sum);
                assert!(m.used() <= m.budget, "used must track the live budget");
                assert_eq!(m.headroom(), m.budget - m.used());
                assert!(m.peak() >= m.used());
                assert!(m.peak() >= last_peak, "peak stays monotone across rebase");
                last_peak = m.peak();
            }
        });
    }
}
