//! Child-thread component prefetcher (paper Sec. 3.3: "the text encoder
//! and the image decoder are loaded interchangeably via a child thread
//! running parallel with the main thread").
//!
//! PJRT handles are not `Send`, so the split is: the child thread runs
//! the *host* half of a load through the shared
//! [`crate::runtime::ArtifactStore`] — disk read, MDWB parse, int8
//! dequantization, each paid at most once per process — while the main
//! thread keeps running denoise steps; the cheap device half (compile,
//! or executable reuse from the warm tier, + buffer upload) happens on
//! the main thread when the prefetch is consumed.  The ledger charges
//! the component at prefetch completion, which is when the bytes are
//! guaranteed to sit in process memory — reproducing the Fig. 4
//! overlap.  On a store hit the "prefetch" is just a cache lookup.
//!
//! Dropping an unconsumed `Prefetcher` joins the child thread: the
//! thread is never leaked past the prefetcher's lifetime, and its
//! store handle is released before `drop` returns.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::runtime::artifact::{ComponentManifest, Manifest};
use crate::runtime::{ArtifactStore, HostArtifact};

/// The host-side half of a loaded component, produced off-thread (or
/// served instantly from the artifact store).
pub struct PrefetchedComponent {
    pub name: String,
    pub host: Arc<HostArtifact>,
    /// the artifact store already held the host half (no disk touched)
    pub store_hit: bool,
    pub stored_bytes: usize,
    pub prefetch_s: f64,
}

pub struct Prefetcher {
    rx: mpsc::Receiver<Result<PrefetchedComponent>>,
    handle: Option<thread::JoinHandle<()>>,
    done: Option<Result<PrefetchedComponent>>,
}

impl Prefetcher {
    /// Start loading `component` (weights tag `tag`) through `store`
    /// on a child thread.
    pub fn spawn(
        store: &Arc<ArtifactStore>,
        manifest: &Manifest,
        comp: &ComponentManifest,
        tag: &str,
    ) -> Result<Prefetcher> {
        let (tx, rx) = mpsc::channel();
        let store = Arc::clone(store);
        let name = comp.name.clone();
        let tag = tag.to_string();
        let hlo_path = manifest.hlo_path(comp);
        let weight_path = manifest.weight_path(comp, &tag)?;
        let handle = thread::Builder::new()
            .name(format!("prefetch-{name}"))
            .spawn(move || {
                let t0 = Instant::now();
                let result = store
                    .get_or_load_paths(&name, &tag, hlo_path, weight_path)
                    .map(|(host, hit)| {
                        let stored = host.stored_bytes();
                        PrefetchedComponent {
                            name,
                            host,
                            store_hit: hit,
                            stored_bytes: stored,
                            prefetch_s: t0.elapsed().as_secs_f64(),
                        }
                    });
                let _ = tx.send(result);
            })
            .map_err(|e| Error::Pipeline(format!("spawn: {e}")))?;
        Ok(Prefetcher { rx, handle: Some(handle), done: None })
    }

    /// Non-blocking readiness poll (called between denoise steps).
    pub fn poll(&mut self) -> bool {
        if self.done.is_some() {
            return true;
        }
        match self.rx.try_recv() {
            Ok(r) => {
                self.done = Some(r);
                true
            }
            Err(mpsc::TryRecvError::Empty) => false,
            Err(mpsc::TryRecvError::Disconnected) => {
                self.done = Some(Err(Error::Pipeline("prefetch thread died".into())));
                true
            }
        }
    }

    /// Block until the prefetch finishes and take the result.
    pub fn join(mut self) -> Result<PrefetchedComponent> {
        let result = match self.done.take() {
            Some(r) => r,
            None => self
                .rx
                .recv()
                .map_err(|_| Error::Pipeline("prefetch thread died".into()))?,
        };
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        result
    }
}

impl Drop for Prefetcher {
    /// An unconsumed prefetch must not leak its thread: cancelling a
    /// request (or failing mid-denoise) joins the child before the
    /// prefetcher goes away.  The host artifact it loaded stays cached
    /// in the store — the work is not wasted, just deferred.
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn tiny_manifest(dir: &std::path::Path, weight_file: &str) -> Manifest {
        let src = format!(
            r#"{{"cfg_batch":2,"latent":{{"size":2,"channels":1}},
                "image":{{"size":4,"channels":3}},
                "components":{{"x":{{"hlo":"x.hlo.txt","variant":"mobile",
                  "params":[],"activations":[],"outputs":[],
                  "param_bytes_f32":0,
                  "weights":{{"fp32":{{"file":"{weight_file}","bytes":0}}}}}}}},
                "scheduler":{{"num_train_timesteps":10,"beta_start":0.1,
                  "beta_end":0.2,"num_inference_steps":2,"guidance_scale":1.0,
                  "alphas_cumprod":[0.9,0.8],"timesteps":[5,0],
                  "golden":{{"latent0":[],"eps_scale":0.1,"trace":[]}}}},
                "tokenizer":{{"vocab_size":16,"seq_len":4,"golden":[]}}}}"#
        );
        let j = Json::parse(&src).unwrap();
        Manifest::from_json(dir, &j).unwrap()
    }

    /// Empty-but-valid MDWB container (zero tensors).
    fn empty_mdwb() -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(b"MDWB");
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&0u32.to_le_bytes());
        out
    }

    #[test]
    fn prefetch_thread_errors_surface() {
        let dir = std::env::temp_dir().join("md_prefetch_test");
        std::fs::create_dir_all(&dir).unwrap();
        let m = tiny_manifest(&dir, "missing.bin");
        let comp = m.component("x").unwrap();
        let store = Arc::new(ArtifactStore::new());
        let p = Prefetcher::spawn(&store, &m, comp, "fp32").unwrap();
        assert!(p.join().is_err());
        assert_eq!(store.disk_loads(), 0, "failed loads are not cached");
    }

    #[test]
    fn dropping_an_unconsumed_prefetch_joins_the_child_thread() {
        let dir = std::env::temp_dir().join("md_prefetch_drop_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("w.bin"), empty_mdwb()).unwrap();
        let m = tiny_manifest(&dir, "w.bin");
        let comp = m.component("x").unwrap();
        let store = Arc::new(ArtifactStore::new());
        {
            let p = Prefetcher::spawn(&store, &m, comp, "fp32").unwrap();
            drop(p); // never polled, never joined by the caller
        }
        // drop joined the thread: its store handle is gone and the
        // load it started has fully landed in the cache
        assert_eq!(Arc::strong_count(&store), 1, "child thread reaped");
        assert_eq!(store.disk_loads(), 1);
        assert_eq!(store.cached(), 1);

        // consuming normally after a previous drop is a store hit
        let p = Prefetcher::spawn(&store, &m, comp, "fp32").unwrap();
        let pf = p.join().unwrap();
        assert!(pf.store_hit, "the dropped prefetch's work was kept");
        assert_eq!(store.disk_loads(), 1);
    }
}
