//! Child-thread component prefetcher (paper Sec. 3.3: "the text encoder
//! and the image decoder are loaded interchangeably via a child thread
//! running parallel with the main thread").
//!
//! PJRT handles are not `Send`, so the split is: the child thread does
//! the heavy, pure-Rust half of a load — disk read of the HLO text and
//! the weight container, MDWB parse, int8 dequantization — while the
//! main thread keeps running denoise steps; the cheap device half
//! (compile + buffer upload) happens on the main thread when the
//! prefetch is consumed.  The ledger charges the component at prefetch
//! completion, which is when the bytes actually sit in process memory —
//! reproducing the Fig. 4 overlap.

use std::path::PathBuf;
use std::sync::mpsc;
use std::thread;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::quant::WeightFile;
use crate::runtime::artifact::{ComponentManifest, Manifest};

/// The host-side half of a loaded component, produced off-thread.
pub struct PrefetchedComponent {
    pub name: String,
    pub hlo_text_path: PathBuf,
    pub weights: WeightFile,
    pub stored_bytes: usize,
    pub prefetch_s: f64,
}

pub struct Prefetcher {
    rx: mpsc::Receiver<Result<PrefetchedComponent>>,
    handle: Option<thread::JoinHandle<()>>,
    done: Option<Result<PrefetchedComponent>>,
}

impl Prefetcher {
    /// Start loading `component` (weights tag `tag`) on a child thread.
    pub fn spawn(manifest: &Manifest, comp: &ComponentManifest, tag: &str) -> Result<Prefetcher> {
        let (tx, rx) = mpsc::channel();
        let name = comp.name.clone();
        let hlo_path = manifest.hlo_path(comp);
        let weight_path = manifest.weight_path(comp, tag)?;
        let handle = thread::Builder::new()
            .name(format!("prefetch-{name}"))
            .spawn(move || {
                let t0 = Instant::now();
                let result = WeightFile::load(&weight_path).map(|weights| {
                    let stored = weights.stored_bytes();
                    PrefetchedComponent {
                        name,
                        hlo_text_path: hlo_path,
                        weights,
                        stored_bytes: stored,
                        prefetch_s: t0.elapsed().as_secs_f64(),
                    }
                });
                let _ = tx.send(result);
            })
            .map_err(|e| Error::Pipeline(format!("spawn: {e}")))?;
        Ok(Prefetcher { rx, handle: Some(handle), done: None })
    }

    /// Non-blocking readiness poll (called between denoise steps).
    pub fn poll(&mut self) -> bool {
        if self.done.is_some() {
            return true;
        }
        match self.rx.try_recv() {
            Ok(r) => {
                self.done = Some(r);
                true
            }
            Err(mpsc::TryRecvError::Empty) => false,
            Err(mpsc::TryRecvError::Disconnected) => {
                self.done = Some(Err(Error::Pipeline("prefetch thread died".into())));
                true
            }
        }
    }

    /// Block until the prefetch finishes and take the result.
    pub fn join(mut self) -> Result<PrefetchedComponent> {
        let result = match self.done.take() {
            Some(r) => r,
            None => self
                .rx
                .recv()
                .map_err(|_| Error::Pipeline("prefetch thread died".into()))?,
        };
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefetch_thread_errors_surface() {
        // fabricate a manifest pointing at a missing weight file
        let dir = std::env::temp_dir().join("md_prefetch_test");
        std::fs::create_dir_all(&dir).unwrap();
        let src = format!(
            r#"{{"cfg_batch":2,"latent":{{"size":2,"channels":1}},
                "image":{{"size":4,"channels":3}},
                "components":{{"x":{{"hlo":"x.hlo.txt","variant":"mobile",
                  "params":[],"activations":[],"outputs":[],
                  "param_bytes_f32":0,
                  "weights":{{"fp32":{{"file":"missing.bin","bytes":0}}}}}}}},
                "scheduler":{{"num_train_timesteps":10,"beta_start":0.1,
                  "beta_end":0.2,"num_inference_steps":2,"guidance_scale":1.0,
                  "alphas_cumprod":[0.9,0.8],"timesteps":[5,0],
                  "golden":{{"latent0":[],"eps_scale":0.1,"trace":[]}}}},
                "tokenizer":{{"vocab_size":16,"seq_len":4,"golden":[]}}}}"#
        );
        let j = crate::util::json::Json::parse(&src).unwrap();
        let m = Manifest::from_json(&dir, &j).unwrap();
        let comp = m.component("x").unwrap();
        let p = Prefetcher::spawn(&m, comp, "fp32").unwrap();
        assert!(p.join().is_err());
    }
}
