//! Component residency — the shared policy layer between the serving
//! stack and the memory ledger.
//!
//! The paper's Sec. 3.3 pipelined executor used to inline its ledger
//! bookkeeping (alloc before load, free after evict, charge prefetches
//! when they land).  That logic now lives here as a reusable subsystem:
//! a [`ResidencyManager`] owns the [`MemoryLedger`], caches loaded
//! components keyed by `(name, weights_tag)`, and exposes
//! `acquire` / `release` / `evict_lru` so executors are pure stage
//! orchestration.
//!
//! Semantics:
//!
//! * **acquire** pins a component, loading it on a cache miss.  Before
//!   a miss loads, least-recently-used *unpinned* entries are evicted
//!   until the new component fits the budget (pinned entries are never
//!   evicted — exceeding the budget with everything pinned is an
//!   error, the condition pipelining exists to avoid).
//! * **release** unpins.  [`Retention::Cache`] keeps the component
//!   resident (still charged to the ledger) for reuse by later
//!   requests — the generalization of the paper's resident UNet.
//!   [`Retention::Evict`] drops it immediately once unpinned — the
//!   paper's behaviour for the text encoder and decoder.
//! * **reserve / fulfill** support the prefetch overlap: the ledger is
//!   charged when the prefetched bytes land in host memory (reserve,
//!   mid-denoise), the device half is attached later (fulfill).
//! * a **warm tier** keeps a small host-side remnant of each evicted
//!   component — for the executor, the compiled executable — so a
//!   post-eviction re-acquire pays only the device upload, never the
//!   read/parse/dequant/compile cold path.  Warm entries live outside
//!   the ledger: it keeps charging only resident device bytes.
//!
//! The manager is generic over the resident payload `C` (and the warm
//! remnant `W`) so the policy can be tested without a PJRT device; the
//! executor instantiates it with `C = Rc<runtime::Component>`,
//! `W = runtime::WarmExecutable`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::pipeline::memory::MemoryLedger;
use crate::pipeline::trace::MemoryTrace;

/// What to do with a component when its last pin is released.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Retention {
    /// Drop it immediately (paper behaviour for text encoder/decoder).
    Evict,
    /// Keep it resident for reuse; evictable under LRU pressure.
    Cache,
}

#[derive(Debug)]
struct Entry<C> {
    name: String,
    tag: String,
    bytes: usize,
    /// logical clock of the last acquire (LRU ordering)
    last_used: u64,
    /// `None` while reserved (prefetch charged but not yet fulfilled)
    payload: Option<C>,
}

impl<C> Entry<C> {
    fn label(&self) -> String {
        format!("{}:{}", self.name, self.tag)
    }
}

/// Pin counts live *outside* the entries, behind an `Arc`, so a
/// [`PinGuard`] can balance them from `Drop` even while the manager is
/// mutably borrowed elsewhere on the stack — the property that makes
/// pins panic-safe (a worker unwinding mid-`acquire` cannot strand a
/// pinned component).
#[derive(Debug, Default)]
struct PinLedger {
    counts: Mutex<BTreeMap<(String, String), usize>>,
    /// pins balanced by a dropped (not disarmed) guard
    auto_released: AtomicU64,
}

impl PinLedger {
    fn pin(&self, name: &str, tag: &str) {
        *self
            .counts
            .lock()
            .unwrap()
            .entry((name.to_string(), tag.to_string()))
            .or_insert(0) += 1;
    }

    /// Decrement; `false` when no pin was outstanding.
    fn unpin(&self, name: &str, tag: &str) -> bool {
        let mut counts = self.counts.lock().unwrap();
        let key = (name.to_string(), tag.to_string());
        match counts.get_mut(&key) {
            Some(n) if *n > 0 => {
                *n -= 1;
                if *n == 0 {
                    counts.remove(&key);
                }
                true
            }
            _ => false,
        }
    }

    fn clear(&self, name: &str, tag: &str) {
        self.counts
            .lock()
            .unwrap()
            .remove(&(name.to_string(), tag.to_string()));
    }

    fn count(&self, name: &str, tag: &str) -> usize {
        *self
            .counts
            .lock()
            .unwrap()
            .get(&(name.to_string(), tag.to_string()))
            .unwrap_or(&0)
    }
}

/// An RAII pin over one `(component, tag)`: if dropped without
/// [`PinGuard::disarm`] — an error unwind, a worker panic mid-request —
/// the pin is released automatically, so the ledger always balances
/// and the component stays evictable.  The happy path disarms the
/// guard and calls [`ResidencyManager::release`] to pick a
/// [`Retention`].
#[derive(Debug)]
pub struct PinGuard {
    ledger: Arc<PinLedger>,
    name: String,
    tag: String,
    armed: bool,
}

impl PinGuard {
    /// Consume the guard without unpinning: the caller takes over the
    /// pin and must balance it with an explicit `release`.
    pub fn disarm(mut self) {
        self.armed = false;
    }
}

impl Drop for PinGuard {
    fn drop(&mut self) {
        if self.armed && self.ledger.unpin(&self.name, &self.tag) {
            self.ledger.auto_released.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// A demoted (evicted) component's host-side remnant.
struct WarmEntry<W> {
    name: String,
    tag: String,
    /// demotion time (oldest is dropped when the tier is full)
    stamp: u64,
    payload: W,
}

/// Owns the memory ledger, the cache of loaded components, and the
/// warm tier of evicted components' host-side remnants.
pub struct ResidencyManager<C, W = ()> {
    ledger: MemoryLedger,
    entries: Vec<Entry<C>>,
    pins: Arc<PinLedger>,
    clock: u64,
    warm: Vec<WarmEntry<W>>,
    warm_capacity: usize,
    /// extracts the warm remnant at eviction; `None` disables the tier
    demote: Option<Box<dyn Fn(&C) -> W>>,
    /// warm remnants handed back to loaders (warm reloads)
    warm_takes: u64,
    /// evictions that stashed a warm remnant
    warm_demotions: u64,
}

impl<C: Clone, W> ResidencyManager<C, W> {
    pub fn new(budget: usize) -> ResidencyManager<C, W> {
        ResidencyManager {
            ledger: MemoryLedger::new(budget),
            entries: Vec::new(),
            pins: Arc::new(PinLedger::default()),
            clock: 0,
            warm: Vec::new(),
            warm_capacity: 0,
            demote: None,
            warm_takes: 0,
            warm_demotions: 0,
        }
    }

    /// Unlimited budget (baseline accounting).
    pub fn unbounded() -> ResidencyManager<C, W> {
        Self::new(usize::MAX)
    }

    /// A manager whose evictions keep up to `warm_capacity` host-side
    /// remnants (extracted by `demote`) for cheap warm reloads.
    pub fn with_warm_tier(
        budget: usize,
        warm_capacity: usize,
        demote: impl Fn(&C) -> W + 'static,
    ) -> ResidencyManager<C, W> {
        let mut m = Self::new(budget);
        m.warm_capacity = warm_capacity;
        m.demote = if warm_capacity > 0 {
            Some(Box::new(demote))
        } else {
            None
        };
        m
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    fn index_of(&self, name: &str, tag: &str) -> Option<usize> {
        self.entries.iter().position(|e| e.name == name && e.tag == tag)
    }

    /// Stash an evicted entry's warm remnant (replacing any older one
    /// under the same key; dropping the oldest entry when full).
    fn stash_warm(&mut self, name: &str, tag: &str, payload: &C) {
        let warm = match self.demote.as_ref() {
            Some(d) => d(payload),
            None => return,
        };
        self.warm.retain(|e| !(e.name == name && e.tag == tag));
        if self.warm.len() >= self.warm_capacity {
            if let Some(oldest) = self
                .warm
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(i, _)| i)
            {
                self.warm.remove(oldest);
            }
        }
        let stamp = self.tick();
        self.warm.push(WarmEntry {
            name: name.to_string(),
            tag: tag.to_string(),
            stamp,
            payload: warm,
        });
        self.warm_demotions += 1;
    }

    /// Take the warm remnant of a previously evicted `(name, tag)`, if
    /// any — the loader passes it back in so the reload skips the cold
    /// stages.  The remnant leaves the tier (the re-loaded component
    /// will be demoted again on its next eviction).
    pub fn take_warm(&mut self, name: &str, tag: &str) -> Option<W> {
        let i = self
            .warm
            .iter()
            .position(|e| e.name == name && e.tag == tag)?;
        self.warm_takes += 1;
        Some(self.warm.remove(i).payload)
    }

    pub fn warm_contains(&self, name: &str, tag: &str) -> bool {
        self.warm.iter().any(|e| e.name == name && e.tag == tag)
    }

    /// Number of warm (evicted, host-side) remnants currently kept.
    pub fn warm_len(&self) -> usize {
        self.warm.len()
    }

    /// Warm remnants handed to loaders so far (warm reloads).
    pub fn warm_takes(&self) -> u64 {
        self.warm_takes
    }

    /// Evictions that kept a warm remnant.
    pub fn warm_demotions(&self) -> u64 {
        self.warm_demotions
    }

    /// Evict LRU unpinned entries until `bytes` more would fit the
    /// budget.  Stops when nothing evictable remains; the subsequent
    /// ledger alloc reports the budget violation with full context.
    fn evict_to_fit(&mut self, bytes: usize) {
        while self.ledger.used().saturating_add(bytes) > self.ledger.budget {
            if self.evict_lru().is_none() {
                break;
            }
        }
    }

    /// Evict the least-recently-used unpinned entry, if any, demoting
    /// its payload into the warm tier.
    /// Returns `(name, tag, bytes)` of the evicted component.
    pub fn evict_lru(&mut self) -> Option<(String, String, usize)> {
        let pins = &self.pins;
        let idx = self
            .entries
            .iter()
            .enumerate()
            .filter(|(_, e)| pins.count(&e.name, &e.tag) == 0)
            .min_by_key(|(_, e)| e.last_used)
            .map(|(i, _)| i)?;
        let e = self.entries.remove(idx);
        // entry exists iff its ledger charge exists; free cannot fail
        let _ = self.ledger.free(&e.label());
        if let Some(p) = e.payload.as_ref() {
            self.stash_warm(&e.name, &e.tag, p);
        }
        Some((e.name, e.tag, e.bytes))
    }

    /// Evict every unpinned cached entry; returns the bytes freed.
    pub fn evict_idle(&mut self) -> usize {
        let mut freed = 0;
        while let Some((_, _, bytes)) = self.evict_lru() {
            freed += bytes;
        }
        freed
    }

    /// Pin `(name, tag)`, loading it via `load` on a cache miss.
    /// `bytes` is the component's resident size (known from the
    /// manifest *before* loading, so the budget check precedes the
    /// load).
    pub fn acquire(
        &mut self,
        name: &str,
        tag: &str,
        bytes: usize,
        load: impl FnOnce() -> Result<C>,
    ) -> Result<C> {
        let now = self.tick();
        if let Some(i) = self.index_of(name, tag) {
            let e = &mut self.entries[i];
            if e.payload.is_none() {
                return Err(Error::Pipeline(format!(
                    "{}: reserved (prefetch in flight), cannot acquire",
                    e.label()
                )));
            }
            e.last_used = now;
            let c = e.payload.as_ref().expect("checked above").clone();
            self.pins.pin(name, tag);
            return Ok(c);
        }
        self.evict_to_fit(bytes);
        let label = format!("{name}:{tag}");
        self.ledger.alloc(&label, bytes)?;
        match load() {
            Ok(c) => {
                self.entries.push(Entry {
                    name: name.to_string(),
                    tag: tag.to_string(),
                    bytes,
                    last_used: now,
                    payload: Some(c.clone()),
                });
                self.pins.pin(name, tag);
                Ok(c)
            }
            Err(e) => {
                let _ = self.ledger.free(&label);
                Err(e)
            }
        }
    }

    /// [`Self::acquire`] returning an RAII [`PinGuard`] alongside the
    /// payload.  On the happy path the caller disarms the guard and
    /// releases explicitly (choosing a [`Retention`]); on any unwind —
    /// error return or panic — the dropped guard balances the pin, so
    /// a worker dying mid-request can never strand a pinned component.
    pub fn acquire_pinned(
        &mut self,
        name: &str,
        tag: &str,
        bytes: usize,
        load: impl FnOnce() -> Result<C>,
    ) -> Result<(C, PinGuard)> {
        let c = self.acquire(name, tag, bytes, load)?;
        Ok((
            c,
            PinGuard {
                ledger: Arc::clone(&self.pins),
                name: name.to_string(),
                tag: tag.to_string(),
                armed: true,
            },
        ))
    }

    /// Pins balanced by a dropped (not disarmed) [`PinGuard`] — each
    /// one is a leak the RAII layer caught.
    pub fn pins_auto_released(&self) -> u64 {
        self.pins.auto_released.load(Ordering::Relaxed)
    }

    /// Unpin `(name, tag)`.  With [`Retention::Evict`] the component is
    /// dropped (and the ledger credited) once no pins remain; with
    /// [`Retention::Cache`] it stays resident for reuse.
    pub fn release(&mut self, name: &str, tag: &str, retention: Retention) -> Result<()> {
        let i = self.index_of(name, tag).ok_or_else(|| {
            Error::Pipeline(format!("{name}:{tag}: release of non-resident component"))
        })?;
        if !self.pins.unpin(name, tag) {
            return Err(Error::Pipeline(format!(
                "{name}:{tag}: release without pin"
            )));
        }
        if retention == Retention::Evict && self.pins.count(name, tag) == 0 {
            let e = self.entries.remove(i);
            let _ = self.ledger.free(&e.label());
            if let Some(p) = e.payload.as_ref() {
                self.stash_warm(&e.name, &e.tag, p);
            }
        }
        Ok(())
    }

    /// Charge the budget for a component whose host bytes just landed
    /// (prefetch completion) without a device payload yet.  The entry
    /// is pinned until `fulfill` or `cancel`.
    pub fn reserve(&mut self, name: &str, tag: &str, bytes: usize) -> Result<()> {
        if self.index_of(name, tag).is_some() {
            return Err(Error::Pipeline(format!("{name}:{tag}: already resident")));
        }
        let now = self.tick();
        self.evict_to_fit(bytes);
        let label = format!("{name}:{tag}");
        self.ledger.alloc(&label, bytes)?;
        self.entries.push(Entry {
            name: name.to_string(),
            tag: tag.to_string(),
            bytes,
            last_used: now,
            payload: None,
        });
        self.pins.pin(name, tag);
        Ok(())
    }

    /// Attach the device payload to a reserved entry and return it
    /// (pinned by the original reserve).
    pub fn fulfill(&mut self, name: &str, tag: &str, payload: C) -> Result<C> {
        let i = self.index_of(name, tag).ok_or_else(|| {
            Error::Pipeline(format!("{name}:{tag}: fulfill without reserve"))
        })?;
        let e = &mut self.entries[i];
        if e.payload.is_some() {
            return Err(Error::Pipeline(format!("{}: already fulfilled", e.label())));
        }
        e.payload = Some(payload.clone());
        Ok(payload)
    }

    /// Drop an entry regardless of pin count (error recovery after a
    /// failed request); returns whether anything was dropped.  The
    /// warm remnant goes with it — after a failure nothing of the
    /// component is trusted for reuse.
    pub fn purge(&mut self, name: &str, tag: &str) -> bool {
        self.warm.retain(|e| !(e.name == name && e.tag == tag));
        self.pins.clear(name, tag);
        match self.index_of(name, tag) {
            Some(i) => {
                let e = self.entries.remove(i);
                let _ = self.ledger.free(&e.label());
                true
            }
            None => false,
        }
    }

    /// Drop a reserved entry (prefetch failed after the charge).
    pub fn cancel(&mut self, name: &str, tag: &str) -> Result<()> {
        let i = self.index_of(name, tag).ok_or_else(|| {
            Error::Pipeline(format!("{name}:{tag}: cancel of non-resident component"))
        })?;
        let e = self.entries.remove(i);
        let _ = self.ledger.free(&e.label());
        self.pins.clear(name, tag);
        Ok(())
    }

    pub fn contains(&self, name: &str, tag: &str) -> bool {
        self.index_of(name, tag).is_some()
    }

    pub fn is_pinned(&self, name: &str, tag: &str) -> bool {
        self.index_of(name, tag).is_some() && self.pins.count(name, tag) > 0
    }

    /// Number of resident (cached or pinned) components.
    pub fn resident_count(&self) -> usize {
        self.entries.len()
    }

    pub fn budget(&self) -> usize {
        self.ledger.budget
    }

    /// Rebase the device memory budget (the memory-pressure governor
    /// shrinking a class's effective budget, or re-probing upward).
    /// Resident components are never invalidated; see
    /// [`MemoryLedger::set_budget`].  Returns the installed budget.
    pub fn set_budget(&mut self, bytes: usize) -> usize {
        self.ledger.set_budget(bytes)
    }

    /// Bytes still allocatable before the budget is hit.
    pub fn headroom(&self) -> usize {
        self.ledger.headroom()
    }

    /// Drop every warm (evicted, host-side) executable remnant —
    /// degradation-ladder rung: warm remnants are not ledger-charged,
    /// but they do hold *device-adjacent host* state the pressure
    /// governor sheds before shrinking budgets.  Returns how many
    /// remnants were dropped.
    pub fn clear_warm(&mut self) -> usize {
        let n = self.warm.len();
        self.warm.clear();
        n
    }

    pub fn used(&self) -> usize {
        self.ledger.used()
    }

    pub fn peak(&self) -> usize {
        self.ledger.peak()
    }

    /// Annotate the occupancy trace (Fig. 4).
    pub fn mark(&mut self, label: &str) {
        self.ledger.mark(label);
    }

    pub fn trace(&self) -> &MemoryTrace {
        &self.ledger.trace
    }

    pub fn ledger(&self) -> &MemoryLedger {
        &self.ledger
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::Cell;

    fn ok(v: u32) -> impl FnOnce() -> Result<u32> {
        move || Ok(v)
    }

    #[test]
    fn acquire_release_evict_roundtrip() {
        let mut r: ResidencyManager<u32> = ResidencyManager::new(100);
        let c = r.acquire("text_encoder", "fp32", 60, ok(7)).unwrap();
        assert_eq!(c, 7);
        assert_eq!(r.used(), 60);
        assert!(r.is_pinned("text_encoder", "fp32"));
        r.release("text_encoder", "fp32", Retention::Evict).unwrap();
        assert!(!r.contains("text_encoder", "fp32"));
        assert_eq!(r.used(), 0);
        assert_eq!(r.peak(), 60);
    }

    #[test]
    fn cache_retention_skips_reload() {
        let mut r: ResidencyManager<u32> = ResidencyManager::new(100);
        let loads = Cell::new(0);
        let load = || {
            loads.set(loads.get() + 1);
            Ok(1)
        };
        r.acquire("unet", "fp32", 50, load).unwrap();
        r.release("unet", "fp32", Retention::Cache).unwrap();
        assert!(r.contains("unet", "fp32"));
        assert!(!r.is_pinned("unet", "fp32"));
        assert_eq!(r.used(), 50, "cached component stays charged");
        r.acquire("unet", "fp32", 50, || {
            loads.set(loads.get() + 1);
            Ok(1)
        })
        .unwrap();
        assert_eq!(loads.get(), 1, "second acquire is a cache hit");
    }

    #[test]
    fn lru_eviction_under_budget_pressure() {
        let mut r: ResidencyManager<u32> = ResidencyManager::new(100);
        r.acquire("a", "fp32", 40, ok(1)).unwrap();
        r.release("a", "fp32", Retention::Cache).unwrap();
        r.acquire("b", "fp32", 40, ok(2)).unwrap();
        r.release("b", "fp32", Retention::Cache).unwrap();
        // c does not fit beside a+b: the least recently used (a) goes
        r.acquire("c", "fp32", 40, ok(3)).unwrap();
        assert!(!r.contains("a", "fp32"), "LRU entry evicted");
        assert!(r.contains("b", "fp32"));
        assert!(r.contains("c", "fp32"));
        assert_eq!(r.used(), 80);
        // touching b makes it most-recent; d evicts nothing pinned
        r.acquire("b", "fp32", 40, ok(2)).unwrap();
        r.release("b", "fp32", Retention::Cache).unwrap();
        r.release("c", "fp32", Retention::Cache).unwrap();
        r.acquire("d", "fp32", 40, ok(4)).unwrap();
        assert!(!r.contains("c", "fp32"), "c was LRU after b's touch");
        assert!(r.contains("b", "fp32"));
    }

    #[test]
    fn pinned_components_are_never_evicted() {
        let mut r: ResidencyManager<u32> = ResidencyManager::new(100);
        r.acquire("a", "fp32", 60, ok(1)).unwrap(); // stays pinned
        let e = r.acquire("b", "fp32", 60, ok(2));
        assert!(e.is_err(), "must not evict the pinned a: {e:?}");
        assert!(r.contains("a", "fp32"));
        assert_eq!(r.used(), 60);
        assert!(r.evict_lru().is_none(), "nothing unpinned to evict");
    }

    #[test]
    fn failed_load_credits_the_ledger() {
        let mut r: ResidencyManager<u32> = ResidencyManager::new(100);
        let e = r.acquire("a", "fp32", 60, || {
            Err(Error::Weights("corrupt".into()))
        });
        assert!(e.is_err());
        assert_eq!(r.used(), 0);
        assert!(!r.contains("a", "fp32"));
    }

    #[test]
    fn reserve_fulfill_cancel_flow() {
        let mut r: ResidencyManager<u32> = ResidencyManager::new(100);
        r.reserve("decoder", "fp32", 70).unwrap();
        assert_eq!(r.used(), 70);
        // reserved entries cannot be acquired or double-reserved
        assert!(r.acquire("decoder", "fp32", 70, ok(9)).is_err());
        assert!(r.reserve("decoder", "fp32", 70).is_err());
        let c = r.fulfill("decoder", "fp32", 9).unwrap();
        assert_eq!(c, 9);
        assert!(r.fulfill("decoder", "fp32", 9).is_err());
        r.release("decoder", "fp32", Retention::Evict).unwrap();
        assert_eq!(r.used(), 0);

        r.reserve("decoder", "fp32", 70).unwrap();
        r.cancel("decoder", "fp32").unwrap();
        assert_eq!(r.used(), 0);
        assert!(!r.contains("decoder", "fp32"));
    }

    #[test]
    fn release_errors_are_reported() {
        let mut r: ResidencyManager<u32> = ResidencyManager::new(100);
        assert!(r.release("ghost", "fp32", Retention::Evict).is_err());
        r.acquire("a", "fp32", 10, ok(1)).unwrap();
        r.release("a", "fp32", Retention::Cache).unwrap();
        assert!(
            r.release("a", "fp32", Retention::Cache).is_err(),
            "release without pin"
        );
    }

    #[test]
    fn purge_drops_even_pinned_entries() {
        let mut r: ResidencyManager<u32> = ResidencyManager::new(100);
        r.acquire("a", "fp32", 10, ok(1)).unwrap(); // pinned
        assert!(r.purge("a", "fp32"));
        assert!(!r.purge("a", "fp32"));
        assert_eq!(r.used(), 0);
    }

    #[test]
    fn evict_idle_frees_everything_unpinned() {
        let mut r: ResidencyManager<u32> = ResidencyManager::new(1000);
        r.acquire("a", "fp32", 100, ok(1)).unwrap();
        r.release("a", "fp32", Retention::Cache).unwrap();
        r.acquire("b", "int8", 200, ok(2)).unwrap();
        r.release("b", "int8", Retention::Cache).unwrap();
        r.acquire("c", "fp32", 50, ok(3)).unwrap(); // pinned
        assert_eq!(r.evict_idle(), 300);
        assert_eq!(r.used(), 50);
        assert_eq!(r.resident_count(), 1);
    }

    #[test]
    fn dropped_pin_guard_balances_the_ledger() {
        let mut r: ResidencyManager<u32> = ResidencyManager::new(100);
        {
            let (c, _guard) = r.acquire_pinned("a", "fp32", 60, ok(1)).unwrap();
            assert_eq!(c, 1);
            assert!(r.is_pinned("a", "fp32"));
            // guard dropped here without disarm — simulating an unwind
        }
        assert!(!r.is_pinned("a", "fp32"), "drop balanced the pin");
        assert_eq!(r.pins_auto_released(), 1);
        assert!(r.contains("a", "fp32"), "component stays resident");
        // and is evictable again: budget pressure can reclaim it
        r.acquire("b", "fp32", 60, ok(2)).unwrap();
        assert!(!r.contains("a", "fp32"), "unpinned entry evicted for b");
    }

    #[test]
    fn disarmed_pin_guard_hands_the_pin_to_release() {
        let mut r: ResidencyManager<u32> = ResidencyManager::new(100);
        let (_c, guard) = r.acquire_pinned("a", "fp32", 60, ok(1)).unwrap();
        guard.disarm();
        assert!(r.is_pinned("a", "fp32"), "disarm keeps the pin");
        assert_eq!(r.pins_auto_released(), 0);
        r.release("a", "fp32", Retention::Evict).unwrap();
        assert!(!r.contains("a", "fp32"));
        assert_eq!(r.used(), 0);
    }

    #[test]
    fn pin_guard_survives_a_purge_without_unbalancing() {
        let mut r: ResidencyManager<u32> = ResidencyManager::new(100);
        let (_c, guard) = r.acquire_pinned("a", "fp32", 60, ok(1)).unwrap();
        assert!(r.purge("a", "fp32"), "purge drops even pinned entries");
        assert_eq!(r.used(), 0);
        drop(guard); // pin already cleared by the purge: a no-op
        assert_eq!(r.pins_auto_released(), 0);
        // the slate is clean for a fresh acquire
        r.acquire("a", "fp32", 60, ok(2)).unwrap();
        assert!(r.is_pinned("a", "fp32"));
    }

    #[test]
    fn panic_mid_request_cannot_strand_a_pin() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let mut r: ResidencyManager<u32> = ResidencyManager::new(100);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let (_c, _guard) = r.acquire_pinned("a", "fp32", 60, ok(1)).unwrap();
            panic!("worker died mid-request");
        }));
        assert!(result.is_err());
        assert!(!r.is_pinned("a", "fp32"), "unwind balanced the pin");
        assert_eq!(r.pins_auto_released(), 1);
        assert!(r.evict_lru().is_some(), "entry reclaimable after the panic");
        assert_eq!(r.used(), 0);
    }

    #[test]
    fn trace_records_tagged_labels() {
        let mut r: ResidencyManager<u32> = ResidencyManager::new(100);
        r.acquire("text_encoder", "fp32", 10, ok(1)).unwrap();
        r.release("text_encoder", "fp32", Retention::Evict).unwrap();
        let s = r.trace().render_ascii(20);
        assert!(s.contains("+text_encoder"), "{s}");
        assert!(s.contains("-text_encoder"), "{s}");
    }

    /// Warm-tier manager over u32 payloads whose warm remnant is the
    /// payload itself.
    fn warm_mgr(budget: usize, cap: usize) -> ResidencyManager<u32, u32> {
        ResidencyManager::with_warm_tier(budget, cap, |c: &u32| *c)
    }

    #[test]
    fn set_budget_and_clear_warm_support_the_pressure_ladder() {
        let mut r = warm_mgr(100, 4);
        r.acquire("text_encoder", "fp32", 60, ok(7)).unwrap();
        r.release("text_encoder", "fp32", Retention::Evict).unwrap();
        assert_eq!(r.warm_len(), 1);
        assert_eq!(r.clear_warm(), 1, "warm remnants shed under pressure");
        assert_eq!(r.warm_len(), 0);

        r.acquire("unet_mobile", "fp32", 80, ok(1)).unwrap();
        // shrink below residency: clamped to the pinned bytes
        assert_eq!(r.set_budget(40), 80);
        assert_eq!(r.headroom(), 0);
        r.release("unet_mobile", "fp32", Retention::Evict).unwrap();
        assert_eq!(r.set_budget(40), 40);
        assert_eq!(r.budget(), 40);
        // re-probe upward restores the shipped budget
        assert_eq!(r.set_budget(100), 100);
        assert_eq!(r.headroom(), 100);
    }

    #[test]
    fn eviction_demotes_into_the_warm_tier_outside_the_ledger() {
        let mut r = warm_mgr(100, 4);
        r.acquire("text_encoder", "fp32", 60, ok(7)).unwrap();
        r.release("text_encoder", "fp32", Retention::Evict).unwrap();
        assert!(!r.contains("text_encoder", "fp32"));
        assert!(r.warm_contains("text_encoder", "fp32"));
        assert_eq!(r.used(), 0, "warm remnants are never ledger-charged");
        assert_eq!(r.warm_demotions(), 1);
        // a warm reload takes the remnant back out
        assert_eq!(r.take_warm("text_encoder", "fp32"), Some(7));
        assert_eq!(r.warm_takes(), 1);
        assert!(!r.warm_contains("text_encoder", "fp32"));
        assert_eq!(r.take_warm("text_encoder", "fp32"), None);
    }

    #[test]
    fn lru_pressure_eviction_also_demotes() {
        let mut r = warm_mgr(100, 4);
        r.acquire("a", "fp32", 60, ok(1)).unwrap();
        r.release("a", "fp32", Retention::Cache).unwrap();
        r.acquire("b", "fp32", 60, ok(2)).unwrap();
        assert!(!r.contains("a", "fp32"), "a evicted for b");
        assert_eq!(r.take_warm("a", "fp32"), Some(1));
    }

    #[test]
    fn warm_tier_capacity_drops_the_oldest_remnant() {
        let mut r = warm_mgr(1000, 2);
        for (i, name) in ["a", "b", "c"].iter().enumerate() {
            r.acquire(name, "fp32", 10, ok(i as u32)).unwrap();
            r.release(name, "fp32", Retention::Evict).unwrap();
        }
        assert_eq!(r.warm_len(), 2);
        assert!(!r.warm_contains("a", "fp32"), "oldest remnant dropped");
        assert!(r.warm_contains("b", "fp32"));
        assert!(r.warm_contains("c", "fp32"));
    }

    #[test]
    fn purge_invalidates_the_warm_remnant_too() {
        let mut r = warm_mgr(100, 4);
        r.acquire("a", "fp32", 10, ok(1)).unwrap();
        r.release("a", "fp32", Retention::Evict).unwrap();
        assert!(r.warm_contains("a", "fp32"));
        r.purge("a", "fp32");
        assert!(!r.warm_contains("a", "fp32"));
    }

    #[test]
    fn zero_capacity_disables_the_tier() {
        let mut r = warm_mgr(100, 0);
        r.acquire("a", "fp32", 10, ok(1)).unwrap();
        r.release("a", "fp32", Retention::Evict).unwrap();
        assert_eq!(r.warm_len(), 0);
        assert_eq!(r.take_warm("a", "fp32"), None);
    }

    #[test]
    fn same_name_different_tags_coexist() {
        let mut r: ResidencyManager<u32> = ResidencyManager::new(1000);
        r.acquire("unet", "fp32", 400, ok(1)).unwrap();
        r.acquire("unet", "int8", 100, ok(2)).unwrap();
        assert_eq!(r.used(), 500);
        assert_eq!(r.resident_count(), 2);
        r.release("unet", "fp32", Retention::Evict).unwrap();
        assert_eq!(r.used(), 100);
        assert!(r.contains("unet", "int8"));
    }
}
