//! Cross-request micro-batching for the denoise loop.
//!
//! Two pieces live here:
//!
//! * **Batch formation** ([`form_batches`]): concurrent requests are
//!   compatible when they run the same UNet executable *and* the same
//!   solver — same `(variant, weights_tag, sampler)` [`BatchKey`].
//!   Step counts and guidance scales do *not* split batches: guidance
//!   is applied on the host per request, and the stepwise loop passes
//!   a per-CFG-row timestep, so requests on different schedules share
//!   dispatches until their schedules run out, at which point they
//!   leave the batch and the remaining stragglers continue (eventually
//!   solo) — no request ever waits for a longer-scheduled peer.
//!   Samplers *do* split batches: a multistep row carries solver state
//!   (its eps history) whose update order is part of the numerics, so
//!   only solver-compatible rows ever share CFG dispatches.  Under step-level continuous
//!   batching ([`crate::pipeline::continuous`]) membership is fully
//!   dynamic: rows also *join* mid-flight (each starting at its own
//!   schedule head) and freed straggler slots are refilled from the
//!   queue, with [`StepBuffers::repack`] rebuilding the composition at
//!   the step boundary.  Only rows sharing a [`BatchKey`] ever share a
//!   composition.
//! * **The zero-realloc step plan** ([`StepBuffers`]): host staging
//!   vectors and device buffers for the latent, timestep and context
//!   activations are allocated once per batch composition.  Each step
//!   rewrites the latent/timestep device buffers *in place*
//!   (`write_buffer_f32`) and reads the dispatch output into reused
//!   vectors — after the first step of a composition the loop performs
//!   no host allocations and creates no device buffers.  This replaces
//!   the seed loop's per-step `latent2.clone()` / `vec![t]` uploads.
//!
//! Batching changes activation shapes: a batch of `B` requests packs
//! `B * cfg_rows` CFG rows into one dispatch (`cfg_rows` = 2: uncond
//! then cond per request, matching the solo layout).  Real AOT
//! executables are compiled per batch size; the vendored stub accepts
//! any leading dimension and stands in for that executable set.  A
//! model whose timestep input is a per-dispatch scalar (leading dim 1,
//! the legacy artifact layout) cannot carry per-request timesteps, so
//! [`supports_microbatch`] gates batches of more than one request on
//! every activation being batch-major.

use crate::error::{Error, Result};
use crate::pipeline::executor::ExecOverrides;
use crate::runtime::{write_buffer_f32, Component, Engine, Manifest};
use crate::scheduler::Sampler;

/// One generation request inside a micro-batch.
#[derive(Debug, Clone)]
pub struct BatchRequest {
    pub prompt: String,
    pub seed: u64,
    pub overrides: ExecOverrides,
}

impl BatchRequest {
    pub fn new(prompt: &str, seed: u64) -> BatchRequest {
        BatchRequest {
            prompt: prompt.to_string(),
            seed,
            overrides: ExecOverrides::default(),
        }
    }
}

/// Requests sharing a key run the same UNet executable with the same
/// solver and may share denoise dispatches.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BatchKey {
    pub variant: String,
    pub weights_tag: String,
    pub sampler: Sampler,
}

/// A formed batch: positions into the submitted request slice, all
/// carrying the same [`BatchKey`], in submission order.
#[derive(Debug, Clone)]
pub struct BatchGroup {
    pub key: BatchKey,
    pub indices: Vec<usize>,
}

/// Partition `reqs` into compatible groups of at most `max_batch`,
/// first-fit in submission order (a request joins the earliest open
/// compatible group, so co-batched requests preserve FIFO order).
pub fn form_batches(
    reqs: &[BatchRequest],
    default_variant: &str,
    weights_tag: &str,
    default_sampler: Sampler,
    max_batch: usize,
) -> Vec<BatchGroup> {
    let cap = max_batch.max(1);
    let mut groups: Vec<BatchGroup> = Vec::new();
    for (i, r) in reqs.iter().enumerate() {
        let key = BatchKey {
            variant: r
                .overrides
                .variant
                .clone()
                .unwrap_or_else(|| default_variant.to_string()),
            weights_tag: weights_tag.to_string(),
            sampler: r.overrides.sampler.unwrap_or(default_sampler),
        };
        match groups
            .iter_mut()
            .find(|g| g.key == key && g.indices.len() < cap)
        {
            Some(g) => g.indices.push(i),
            None => groups.push(BatchGroup { key, indices: vec![i] }),
        }
    }
    groups
}

/// Whether a variant's UNet can take micro-batches of more than one
/// request: every declared activation must be batch-major (leading
/// dimension == the manifest's CFG rows) so all inputs scale together,
/// including a per-CFG-row timestep.  Legacy artifacts with a
/// per-dispatch scalar timestep (leading dim 1) fail this and fall
/// back to solo execution.  Checked against the manifest (not a loaded
/// component) so batch formation never forces a load.
pub fn supports_microbatch(manifest: &Manifest, variant: &str) -> bool {
    let name = format!("unet_{variant}");
    match manifest.component(&name) {
        Ok(c) => {
            let rows = manifest.cfg_batch;
            !c.activations.is_empty()
                && c.activations.iter().all(|a| a.shape.first() == Some(&rows))
        }
        Err(_) => false,
    }
}

/// Reusable device-buffer plan for one batch composition of the
/// denoise loop.  Activation argument order is the UNet's manifest
/// order: 0 = latent, 1 = timestep, 2 = context.
pub struct StepBuffers {
    /// requests currently packed
    batch: usize,
    /// CFG rows per request in the latent/context inputs (2)
    lat_rows: usize,
    /// timestep rows per request (1 legacy scalar, or == lat_rows)
    t_rows: usize,
    /// latent elements per CFG row
    row_elems: usize,
    lat_host: Vec<f32>,
    t_host: Vec<f32>,
    lat_buf: Option<xla::PjRtBuffer>,
    t_buf: Option<xla::PjRtBuffer>,
    ctx_buf: Option<xla::PjRtBuffer>,
    /// dispatch outputs, capacity reused across steps
    pub out: Vec<Vec<f32>>,
}

impl StepBuffers {
    /// Size the plan from the UNet's declared activation shapes; host
    /// staging is reserved for `max_batch` requests up front so later
    /// repacks never grow it.
    pub fn for_unet(unet: &Component, max_batch: usize) -> Result<StepBuffers> {
        if unet.act_shapes.len() != 3 {
            return Err(Error::Runtime(format!(
                "{}: denoise expects 3 activations (latent, t, context), got {}",
                unet.name,
                unet.act_shapes.len()
            )));
        }
        let lat = &unet.act_shapes[0];
        let lat_rows = *lat.first().ok_or_else(|| {
            Error::Runtime(format!("{}: rank-0 latent activation", unet.name))
        })?;
        if lat_rows != 2 {
            return Err(Error::Runtime(format!(
                "{}: unsupported CFG layout (want 2 rows/request, got {lat_rows})",
                unet.name
            )));
        }
        let row_elems: usize = lat[1..].iter().product();
        let t_rows: usize = unet.act_shapes[1].iter().product::<usize>().max(1);
        let cap = max_batch.max(1);
        Ok(StepBuffers {
            batch: 0,
            lat_rows,
            t_rows,
            row_elems,
            lat_host: Vec::with_capacity(cap * lat_rows * row_elems),
            t_host: Vec::with_capacity(cap * t_rows),
            lat_buf: None,
            t_buf: None,
            ctx_buf: None,
            out: Vec::new(),
        })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn row_elems(&self) -> usize {
        self.row_elems
    }

    /// Rebuild for a new batch composition: upload the packed context
    /// rows (constant for the composition's lifetime) and drop the
    /// stale latent/timestep buffers so the next dispatch recreates
    /// them at the new size.  Called once per composition, not per
    /// step.
    pub fn repack(
        &mut self,
        engine: &Engine,
        unet: &Component,
        ctx: &[f32],
        batch: usize,
    ) -> Result<()> {
        self.batch = batch;
        self.lat_host.clear();
        self.lat_host.resize(batch * self.lat_rows * self.row_elems, 0.0);
        self.t_host.clear();
        self.t_host.resize(batch * self.t_rows, 0.0);
        self.ctx_buf = Some(unet.upload_f32_rows(engine, 2, ctx, batch)?);
        self.lat_buf = None;
        self.t_buf = None;
        Ok(())
    }

    /// Stage one request's step inputs: its latent replicated into both
    /// CFG rows of batch position `member`, and its current timestep.
    pub fn pack(&mut self, member: usize, latent: &[f32], t: f32) {
        debug_assert_eq!(latent.len(), self.row_elems);
        for r in 0..self.lat_rows {
            let at = (member * self.lat_rows + r) * self.row_elems;
            self.lat_host[at..at + self.row_elems].copy_from_slice(latent);
        }
        for r in 0..self.t_rows {
            self.t_host[member * self.t_rows + r] = t;
        }
    }

    /// One denoise dispatch over the staged batch.  The first dispatch
    /// of a composition creates the latent/timestep buffers; every
    /// later one rewrites them in place — zero allocations, zero new
    /// device buffers.  Results land in `self.out`.
    pub fn dispatch(&mut self, engine: &Engine, unet: &Component) -> Result<()> {
        match (self.lat_buf.as_mut(), self.t_buf.as_mut()) {
            (Some(lb), Some(tb)) => {
                write_buffer_f32(lb, &self.lat_host)?;
                write_buffer_f32(tb, &self.t_host)?;
            }
            _ => {
                self.lat_buf =
                    Some(unet.upload_f32_rows(engine, 0, &self.lat_host, self.batch)?);
                self.t_buf =
                    Some(unet.upload_f32_rows(engine, 1, &self.t_host, self.batch)?);
            }
        }
        let acts = [
            self.lat_buf.as_ref().expect("latent buffer present"),
            self.t_buf.as_ref().expect("timestep buffer present"),
            self.ctx_buf.as_ref().ok_or_else(|| {
                Error::Runtime("StepBuffers::dispatch before repack".into())
            })?,
        ];
        unet.run_buffers_into(&acts, &mut self.out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(variant: Option<&str>) -> BatchRequest {
        let mut r = BatchRequest::new("p", 1);
        r.overrides.variant = variant.map(|v| v.to_string());
        r
    }

    #[test]
    fn compatible_requests_group_up_to_max_batch() {
        let reqs: Vec<BatchRequest> = (0..5).map(|_| req(None)).collect();
        let groups = form_batches(&reqs, "mobile", "fp32", Sampler::Ddim, 4);
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].indices, vec![0, 1, 2, 3]);
        assert_eq!(groups[1].indices, vec![4]);
        assert_eq!(groups[0].key.variant, "mobile");
        assert_eq!(groups[0].key.weights_tag, "fp32");
        assert_eq!(groups[0].key.sampler, Sampler::Ddim);
    }

    #[test]
    fn incompatible_variants_split_groups() {
        let reqs = vec![req(None), req(Some("base")), req(Some("mobile")), req(Some("base"))];
        let groups = form_batches(&reqs, "mobile", "fp32", Sampler::Ddim, 8);
        // default variant "mobile" groups with the explicit "mobile"
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].key.variant, "mobile");
        assert_eq!(groups[0].indices, vec![0, 2]);
        assert_eq!(groups[1].key.variant, "base");
        assert_eq!(groups[1].indices, vec![1, 3]);
    }

    #[test]
    fn mismatched_num_steps_stay_in_one_group() {
        // schedules diverge inside the denoise loop, not at formation
        let mut a = req(None);
        a.overrides.num_steps = Some(4);
        let mut b = req(None);
        b.overrides.num_steps = Some(20);
        let groups = form_batches(&[a, b], "mobile", "fp32", Sampler::Ddim, 4);
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].indices, vec![0, 1]);
    }

    #[test]
    fn mismatched_samplers_split_groups() {
        // solver state makes samplers part of the compatibility key;
        // an explicit default sampler still groups with no-override
        let mut a = req(None);
        a.overrides.sampler = Some(Sampler::Dpm2m);
        let b = req(None);
        let mut c = req(None);
        c.overrides.sampler = Some(Sampler::Ddim);
        let mut d = req(None);
        d.overrides.sampler = Some(Sampler::Dpm2m);
        let groups = form_batches(&[a, b, c, d], "mobile", "fp32", Sampler::Ddim, 8);
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].key.sampler, Sampler::Dpm2m);
        assert_eq!(groups[0].indices, vec![0, 3]);
        assert_eq!(groups[1].key.sampler, Sampler::Ddim);
        assert_eq!(groups[1].indices, vec![1, 2]);
    }

    #[test]
    fn max_batch_zero_is_treated_as_one() {
        let reqs = vec![req(None), req(None)];
        let groups = form_batches(&reqs, "mobile", "fp32", Sampler::Ddim, 0);
        assert_eq!(groups.len(), 2);
    }
}
