//! Memory-occupancy trace: the data behind the paper's Fig. 4.

use std::time::Instant;

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    Alloc,
    Free,
    Mark,
}

#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub t: f64, // seconds since trace start
    pub kind: EventKind,
    pub label: String,
    pub bytes: usize,
    pub total: usize,
}

impl TraceEvent {
    pub fn alloc(label: &str, bytes: usize, total: usize) -> Self {
        TraceEvent { t: 0.0, kind: EventKind::Alloc, label: label.into(), bytes, total }
    }
    pub fn free(label: &str, bytes: usize, total: usize) -> Self {
        TraceEvent { t: 0.0, kind: EventKind::Free, label: label.into(), bytes, total }
    }
    pub fn mark(label: &str, total: usize) -> Self {
        TraceEvent { t: 0.0, kind: EventKind::Mark, label: label.into(), bytes: 0, total }
    }
}

#[derive(Debug)]
pub struct MemoryTrace {
    pub start: Instant,
    pub events: Vec<TraceEvent>,
}

impl Default for MemoryTrace {
    fn default() -> Self {
        Self::new()
    }
}

impl MemoryTrace {
    pub fn new() -> MemoryTrace {
        MemoryTrace { start: Instant::now(), events: Vec::new() }
    }

    pub fn push(&mut self, mut ev: TraceEvent) {
        ev.t = self.start.elapsed().as_secs_f64();
        self.events.push(ev);
    }

    pub fn peak(&self) -> usize {
        self.events.iter().map(|e| e.total).max().unwrap_or(0)
    }

    /// Fig.-4-style ASCII occupancy chart: one row per event, a bar of
    /// total residency, annotated with the event.
    pub fn render_ascii(&self, width: usize) -> String {
        let peak = self.peak().max(1);
        let mut out = String::new();
        out.push_str(&format!(
            "{:>8}  {:<28} {:>10}  occupancy (peak {:.1} MB)\n",
            "t (s)", "event", "total MB", peak as f64 / 1e6
        ));
        for e in &self.events {
            let bar_len = (e.total as f64 / peak as f64 * width as f64).round() as usize;
            let kind = match e.kind {
                EventKind::Alloc => "+",
                EventKind::Free => "-",
                EventKind::Mark => "|",
            };
            out.push_str(&format!(
                "{:>8.3}  {:<28} {:>10.1}  {}\n",
                e.t,
                format!("{}{}", kind, e.label),
                e.total as f64 / 1e6,
                "#".repeat(bar_len)
            ));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::arr(self.events.iter().map(|e| {
            Json::obj(vec![
                ("t", Json::num(e.t)),
                (
                    "kind",
                    Json::str(match e.kind {
                        EventKind::Alloc => "alloc",
                        EventKind::Free => "free",
                        EventKind::Mark => "mark",
                    }),
                ),
                ("label", Json::str(&e.label)),
                ("bytes", Json::num(e.bytes as f64)),
                ("total", Json::num(e.total as f64)),
            ])
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_and_peak() {
        let mut tr = MemoryTrace::new();
        tr.push(TraceEvent::alloc("a", 100, 100));
        tr.push(TraceEvent::alloc("b", 50, 150));
        tr.push(TraceEvent::free("a", 100, 50));
        assert_eq!(tr.peak(), 150);
        assert!(tr.events.windows(2).all(|w| w[0].t <= w[1].t));
    }

    #[test]
    fn ascii_contains_events() {
        let mut tr = MemoryTrace::new();
        tr.push(TraceEvent::alloc("unet", 100, 100));
        tr.push(TraceEvent::mark("denoise", 100));
        let s = tr.render_ascii(40);
        assert!(s.contains("+unet"));
        assert!(s.contains("|denoise"));
    }

    #[test]
    fn json_round_trip() {
        let mut tr = MemoryTrace::new();
        tr.push(TraceEvent::alloc("x", 1, 1));
        let j = tr.to_json();
        assert_eq!(j.at(0).get("label").as_str(), Some("x"));
    }
}
