//! Pipelined execution (paper Sec. 3.3): memory ledger + occupancy
//! trace, store-backed child-thread component prefetch, the shared
//! component residency layer (with its warm executable tier), the
//! cross-request micro-batcher with its step-level continuous-batching
//! row lifecycle, and the stage-interleaved executor.

pub mod batch;
pub mod continuous;
pub mod executor;
pub mod loader;
pub mod memory;
pub mod residency;
pub mod trace;

pub use batch::{form_batches, BatchGroup, BatchKey, BatchRequest, StepBuffers};
pub use continuous::{
    Checkpoint, ContinuousControl, ContinuousJob, LiveRow, NullControl, SessionStats,
};
pub use executor::{
    DispatchObserver, ExecOptions, ExecOverrides, GenerateResult, LoadProfile,
    PipelinedExecutor, ResidentComponent, StageTimings,
};
pub use loader::{PrefetchedComponent, Prefetcher};
pub use memory::MemoryLedger;
pub use residency::{PinGuard, ResidencyManager, Retention};
pub use trace::{EventKind, MemoryTrace, TraceEvent};
