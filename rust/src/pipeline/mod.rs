//! Pipelined execution (paper Sec. 3.3): memory ledger + occupancy
//! trace, child-thread component prefetch, and the stage-interleaved
//! executor.

pub mod executor;
pub mod loader;
pub mod memory;
pub mod trace;

pub use executor::{ExecOptions, GenerateResult, PipelinedExecutor, StageTimings};
pub use loader::{PrefetchedComponent, Prefetcher};
pub use memory::MemoryLedger;
pub use trace::{EventKind, MemoryTrace, TraceEvent};
