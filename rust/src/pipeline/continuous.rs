//! Step-level continuous batching: the row lifecycle contract between
//! the executor (which owns the denoise loop) and the scheduler that
//! feeds it (the pool's continuous worker loop, or a scripted control
//! in tests).
//!
//! A *session* is one occupancy period of a worker's UNet: it starts
//! with whatever compatible jobs the queue held at pop time and then,
//! at every denoise-step boundary, may
//!
//! * **join** — splice newly queued compatible rows into the running
//!   batch ([`ContinuousControl::poll_joins`]); a joiner starts at its
//!   own schedule head, the in-flight rows are unaffected;
//! * **leave** — retire rows whose schedule ended, decode them and
//!   hand the freed slots to joiners instead of running the straggler
//!   tail at partial occupancy;
//! * **preempt** — checkpoint a low-priority row (latent + schedule
//!   position, [`Checkpoint`]) and requeue it so an otherwise
//!   infeasible-deadline queue head can take its slot
//!   ([`ContinuousControl::preempt_victims`]).
//!
//! The invariant inherited from the micro-batch work (and pinned by
//! its parity tests): a row's numerics never depend on its batch
//! position, its batchmates, or when it joined — every row is
//! bit-identical to a solo run with the same seed, and a
//! preempted-then-resumed row is bit-identical to an uninterrupted
//! one.  The checkpoint therefore carries everything the denoise
//! arithmetic consumes (schedule, position, latent, guidance, encoded
//! context, and the solver's eps history for multistep samplers) and
//! nothing derived from batch composition.  Solver state is restored
//! from the checkpoint, never recomputed: a multistep row resumed
//! mid-schedule extrapolates from exactly the eps prediction it would
//! have held uninterrupted.

use crate::error::{Error, Result};
use crate::pipeline::batch::{BatchKey, BatchRequest};
use crate::pipeline::executor::GenerateResult;

/// Mid-flight state of a preempted row — everything needed to resume
/// the denoise loop bit-identically in a later session, with no
/// re-encode (the context rides along) and no re-randomization (the
/// latent is the checkpointed one, not a reseed).
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// the row's full step schedule (descending timesteps)
    pub ts: Vec<usize>,
    /// next schedule index to run; steps `0..pos` are already applied
    pub pos: usize,
    /// latent after `pos` applied steps
    pub latent: Vec<f32>,
    pub guidance: f64,
    /// encoded cond context for the row's prompt
    pub cond: Vec<f32>,
    /// the solver's bounded history of previous (guided) eps
    /// predictions, oldest first — empty for first-order samplers.
    /// Part of the row's numerics, so it checkpoints and resumes
    /// rather than being rebuilt (rebuilding would need the already-
    /// consumed latents).
    pub history: Vec<Vec<f32>>,
    /// worker-busy seconds already attributed to the row
    pub busy_s: f64,
    /// denoise wall seconds already attributed to the row
    pub denoise_s: f64,
}

/// One request entering a continuous session, either fresh or resuming
/// from a preemption checkpoint.  `token` is the caller's identity for
/// the row in every control callback; the executor never interprets it.
pub struct ContinuousJob {
    pub req: BatchRequest,
    pub token: u64,
    pub resume: Option<Checkpoint>,
}

/// Scheduling-relevant view of a live row, handed to
/// [`ContinuousControl::preempt_victims`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LiveRow {
    pub token: u64,
    pub steps_remaining: usize,
}

/// Counters for one continuous session (one worker occupancy period).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SessionStats {
    /// UNet dispatches the session ran
    pub steps: usize,
    /// rows spliced in after the first dispatch
    pub joins: usize,
    /// rows that finished while batchmates stayed live
    pub leaves: usize,
    /// rows checkpointed and requeued
    pub preemptions: usize,
    /// rows admitted from a checkpoint
    pub resumes: usize,
    /// rows that reached a terminal outcome (decoded or failed)
    pub completed: usize,
    /// most rows live in any one dispatch
    pub peak_occupancy: usize,
}

/// How the executor's continuous session talks to its scheduler.  The
/// pool implements this against the shared [`JobQueue`]; tests script
/// it for deterministic join/preempt timing.
///
/// [`JobQueue`]: crate::coordinator::JobQueue
pub trait ContinuousControl {
    /// Called at a step boundary with `slots` free seats (and when the
    /// batch has drained entirely).  Returned jobs are spliced into
    /// the batch before the next dispatch; they must be compatible
    /// with `key` — the executor requeues any that are not, untouched.
    fn poll_joins(&mut self, key: &BatchKey, slots: usize) -> Vec<ContinuousJob>;

    /// Called after every dispatch with the live rows and the free
    /// seat count.  Tokens returned are checkpointed and handed back
    /// through [`Self::requeue`]; unknown tokens are ignored.  Return
    /// none unless the queue head cannot meet its deadline otherwise.
    fn preempt_victims(&mut self, live: &[LiveRow], free_slots: usize) -> Vec<u64>;

    /// A job leaving the session without completing: a preemption
    /// checkpoint (`resume` is `Some`), or an incompatible joiner
    /// bounced untouched (`resume` as it arrived).
    fn requeue(&mut self, job: ContinuousJob);

    /// A *transient* device failure checkpointed this row out of the
    /// session (`resume` holds its progress; the step that faulted was
    /// never applied, so resuming is bit-identical to an uninterrupted
    /// run).  The default treats it like any other requeue; the pool
    /// overrides it to enforce a bounded retry budget with exponential
    /// backoff, failing rows whose budget is exhausted.
    fn retry(&mut self, job: ContinuousJob, cause: &Error) {
        let _ = cause;
        self.requeue(job);
    }

    /// Terminal outcome for a row.
    fn complete(&mut self, token: u64, result: Result<GenerateResult>);

    /// Step telemetry: rows live in the dispatch and its wall seconds.
    fn on_step(&mut self, _live: usize, _wall_s: f64) {}
}

/// A control that never joins or preempts: the session runs its
/// initial rows to completion, collecting outcomes — run-to-completion
/// semantics on the continuous machinery (tests, solo drivers).
#[derive(Default)]
pub struct NullControl {
    pub completions: Vec<(u64, Result<GenerateResult>)>,
}

impl ContinuousControl for NullControl {
    fn poll_joins(&mut self, _key: &BatchKey, _slots: usize) -> Vec<ContinuousJob> {
        Vec::new()
    }

    fn preempt_victims(&mut self, _live: &[LiveRow], _free_slots: usize) -> Vec<u64> {
        Vec::new()
    }

    fn requeue(&mut self, _job: ContinuousJob) {}

    fn complete(&mut self, token: u64, result: Result<GenerateResult>) {
        self.completions.push((token, result));
    }
}
