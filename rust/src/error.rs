//! Crate-wide error type.

use thiserror::Error;

#[derive(Debug, Error)]
pub enum Error {
    #[error("io error: {0}")]
    Io(String),
    #[error("graph error: {0}")]
    Graph(String),
    #[error("manifest error: {0}")]
    Manifest(String),
    #[error("weights error: {0}")]
    Weights(String),
    #[error("runtime error: {0}")]
    Runtime(String),
    #[error("pipeline error: {0}")]
    Pipeline(String),
    #[error("config error: {0}")]
    Config(String),
    #[error("xla error: {0}")]
    Xla(String),
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
