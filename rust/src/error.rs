//! Crate-wide error type (hand-rolled: no proc-macro deps offline).

use std::fmt;

#[derive(Debug, Clone)]
pub enum Error {
    Io(String),
    Graph(String),
    Manifest(String),
    Weights(String),
    Runtime(String),
    Pipeline(String),
    Config(String),
    Queue(String),
    Xla(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(m) => write!(f, "io error: {m}"),
            Error::Graph(m) => write!(f, "graph error: {m}"),
            Error::Manifest(m) => write!(f, "manifest error: {m}"),
            Error::Weights(m) => write!(f, "weights error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Pipeline(m) => write!(f, "pipeline error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Queue(m) => write!(f, "queue error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
