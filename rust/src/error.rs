//! Crate-wide error type (hand-rolled: no proc-macro deps offline).
//!
//! Errors split into three recovery classes the serving stack acts on
//! (see DESIGN.md "Failure domains & recovery" and "Memory pressure &
//! degradation ladder"):
//!
//! * **Transient** ([`Error::Transient`]) — the same operation is
//!   expected to succeed on retry; the pool checkpoints and requeues
//!   affected rows with bounded retry + backoff.
//! * **Out of memory** ([`Error::Oom`]) — the device allocator is
//!   exhausted.  Retrying the *identical* plan against the same
//!   exhausted device is pointless; the pool retries only after the
//!   memory-pressure governor has degraded the plan (smaller seat cap,
//!   evicted residency, reduced effective budget) — never verbatim.
//! * **Fatal** (everything else) — retrying is pointless; the row is
//!   failed.  [`Error::DeviceLost`] is fatal *for the device*: its
//!   in-flight rows are retried elsewhere and the worker restarts
//!   with a fresh engine.

use std::fmt;

#[derive(Debug, Clone)]
pub enum Error {
    Io(String),
    Graph(String),
    Manifest(String),
    Weights(String),
    Runtime(String),
    Pipeline(String),
    Config(String),
    Queue(String),
    Xla(String),
    /// Recoverable device hiccup: retry after backoff.
    Transient(String),
    /// Device allocator exhausted.  Not a garden-variety transient:
    /// retrying the identical plan re-exhausts the same device, so the
    /// pool only retries *degraded* (see `coordinator::pressure`).
    Oom(String),
    /// The device handle is gone; the worker must rebuild its engine.
    DeviceLost(String),
}

impl Error {
    /// Whether the pool should retry the failed work verbatim
    /// (bounded, with exponential backoff) instead of failing it
    /// outright.  OOM is deliberately *not* transient: an unchanged
    /// plan re-exhausts the same allocator, so it retries only through
    /// the degradation path ([`Self::is_oom`]).
    pub fn is_transient(&self) -> bool {
        matches!(self, Error::Transient(_))
    }

    /// Whether the device allocator was exhausted — recoverable, but
    /// only by retrying a *degraded* plan, never the identical one.
    pub fn is_oom(&self) -> bool {
        matches!(self, Error::Oom(_))
    }

    /// Whether the worker's engine is unusable and must be rebuilt.
    pub fn is_device_lost(&self) -> bool {
        matches!(self, Error::DeviceLost(_))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(m) => write!(f, "io error: {m}"),
            Error::Graph(m) => write!(f, "graph error: {m}"),
            Error::Manifest(m) => write!(f, "manifest error: {m}"),
            Error::Weights(m) => write!(f, "weights error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Pipeline(m) => write!(f, "pipeline error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Queue(m) => write!(f, "queue error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Transient(m) => write!(f, "transient device error: {m}"),
            Error::Oom(m) => write!(f, "device oom: {m}"),
            Error::DeviceLost(m) => write!(f, "device lost: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovery_classes() {
        assert!(Error::Transient("x".into()).is_transient());
        assert!(
            !Error::Oom("x".into()).is_transient(),
            "OOM must never be retried verbatim on an unchanged plan"
        );
        assert!(Error::Oom("x".into()).is_oom());
        assert!(!Error::Transient("x".into()).is_oom());
        assert!(!Error::DeviceLost("x".into()).is_oom());
        assert!(!Error::DeviceLost("x".into()).is_transient());
        assert!(Error::DeviceLost("x".into()).is_device_lost());
        assert!(!Error::Xla("x".into()).is_transient());
        assert!(!Error::Queue("x".into()).is_device_lost());
    }
}
