//! Deterministic fake-artifact generation for the vendored xla stub —
//! test and benchmark support, not a production path.
//!
//! The integration surface of the runtime is an artifact directory:
//! `manifest.json`, HLO text per component, MDWB weight containers.
//! Real artifacts come from `python/compile` (`make artifacts`) and
//! need JAX; this module writes a *small, fully synthetic* artifact set
//! whose HLO files are `STUBHLO` programs the vendored stub interprets
//! (see `rust/vendor/xla`).  That lets `cargo test` and `cargo bench`
//! drive the entire serving stack — text encode, batched denoise,
//! decoder prefetch, decode — with real buffers and real dispatch
//! counts, no Python and no PJRT.
//!
//! The UNet declares batch-major activations (leading dim ==
//! `cfg_batch` on latent, timestep *and* context), the shape contract
//! cross-request micro-batching needs; the stub accepts any scaled
//! leading dimension, standing in for a per-batch-size executable set.
//!
//! Also here: [`throughput`], the pool-driving harness shared by
//! `benches/throughput.rs` and the tier-1 smoke test, so the benchmark
//! numbers and the tested invariant (B=4 beats B=1) come from the same
//! code.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::scheduler::{Ddim, SchedulerParams};
use crate::util::rng::Rng;

/// Sizing knobs for a synthetic artifact set.  The UNet weight count is
/// the per-dispatch fixed cost in the stub interpreter (it digests all
/// weights once per dispatch), i.e. the cost micro-batching amortizes.
#[derive(Debug, Clone)]
pub struct FakeArtifactSpec {
    pub latent_size: usize,
    pub latent_channels: usize,
    pub image_size: usize,
    pub seq_len: usize,
    pub context_dim: usize,
    pub vocab_size: usize,
    pub unet_weight_elems: usize,
    pub encoder_weight_elems: usize,
    pub decoder_weight_elems: usize,
    pub num_train_timesteps: usize,
    /// also write an "int8" weight set for the UNets (per-channel
    /// quantized MDWB), giving the load path a real dequant stage —
    /// the cold-vs-warm benchmark runs on these
    pub int8_unet: bool,
}

impl Default for FakeArtifactSpec {
    fn default() -> Self {
        FakeArtifactSpec {
            latent_size: 8,
            latent_channels: 4,
            image_size: 16,
            seq_len: 8,
            context_dim: 16,
            vocab_size: 128,
            unet_weight_elems: 65_536,
            encoder_weight_elems: 2_048,
            decoder_weight_elems: 2_048,
            num_train_timesteps: 1000,
            int8_unet: false,
        }
    }
}

/// One component's synthetic description.
struct FakeComponent {
    name: &'static str,
    variant: &'static str,
    weight_elems: usize,
    /// STUBHLO body after the header
    program: String,
    activations: Vec<(Vec<usize>, &'static str)>,
    outputs: Vec<Vec<usize>>,
}

/// Write a complete synthetic artifact directory.  Overwrites freely —
/// callers own the directory (use a per-test label).
pub fn write_fake_artifacts(dir: &Path, spec: &FakeArtifactSpec) -> Result<()> {
    std::fs::create_dir_all(dir)
        .map_err(|e| Error::Io(format!("{}: {e}", dir.display())))?;

    let s = spec.latent_size;
    let c = spec.latent_channels;
    let seq = spec.seq_len;
    let d = spec.context_dim;
    let img = spec.image_size;

    let unet_acts = vec![
        (vec![2, s, s, c], "float32"),
        (vec![2], "float32"),
        (vec![2, seq, d], "float32"),
    ];
    let comps = [
        FakeComponent {
            name: "text_encoder",
            variant: "mobile",
            weight_elems: spec.encoder_weight_elems,
            program: format!(
                "name text_encoder\nmode whole\nnweights 1\nseed 11\nout elems {}\n",
                seq * d
            ),
            activations: vec![(vec![1, seq], "int32")],
            outputs: vec![vec![1, seq, d]],
        },
        FakeComponent {
            name: "unet_base",
            variant: "base",
            weight_elems: spec.unet_weight_elems,
            program: "name unet_base\nmode rowwise\nnweights 1\nseed 21\nout like 0\n"
                .to_string(),
            activations: unet_acts.clone(),
            outputs: vec![vec![2, s, s, c]],
        },
        FakeComponent {
            name: "unet_mobile",
            variant: "mobile",
            weight_elems: spec.unet_weight_elems,
            program: "name unet_mobile\nmode rowwise\nnweights 1\nseed 22\nout like 0\n"
                .to_string(),
            activations: unet_acts,
            outputs: vec![vec![2, s, s, c]],
        },
        FakeComponent {
            name: "decoder",
            variant: "mobile",
            weight_elems: spec.decoder_weight_elems,
            program: format!(
                "name decoder\nmode whole\nnweights 1\nseed 31\nout elems {}\n",
                img * img * 3
            ),
            activations: vec![(vec![1, s, s, c], "float32")],
            outputs: vec![vec![1, img, img, 3]],
        },
    ];

    // per-tensor activation range for W8A8: stub outputs live in
    // [-0.5, 0.5), so one scale covers every component (a real
    // exporter would record ranges during a calibration pass)
    let aquant = crate::quant::stub_activation_scale();

    let mut comp_json = Vec::new();
    for comp in &comps {
        let hlo_file = format!("{}.hlo.txt", comp.name);
        std::fs::write(
            dir.join(&hlo_file),
            format!("STUBHLO v1\n{}aquant {aquant}\n", comp.program),
        )
        .map_err(|e| Error::Io(format!("{hlo_file}: {e}")))?;

        // one f32 weight tensor, values deterministic per component
        let mut rng = Rng::new(comp.name.len() as u64 * 7919 + comp.weight_elems as u64);
        let values: Vec<f32> = (0..comp.weight_elems)
            .map(|_| rng.next_f32() - 0.5)
            .collect();
        // the int8 variant quantizes per output channel, so its UNet
        // tensors carry a 2-D (rows, cout) shape — both weight sets
        // must declare it, the manifest param shape being shared
        let int8_here =
            spec.int8_unet && comp.name.starts_with("unet") && comp.weight_elems % 256 == 0;
        let shape: Vec<usize> = if int8_here {
            vec![comp.weight_elems / 256, 256]
        } else {
            vec![comp.weight_elems]
        };
        let weight_file = format!("weights_{}_fp32.bin", comp.name);
        let path = "blocks/w";
        let bytes = write_mdwb_f32(&dir.join(&weight_file), path, &shape, &values)?;
        let mut weights_json = format!(
            "{{\"fp32\": {{\"file\": \"{weight_file}\", \"bytes\": {bytes}}}"
        );
        if int8_here {
            let (q, scale) = crate::quant::quantize_per_channel(&values, 256);
            let int8_file = format!("weights_{}_int8.bin", comp.name);
            let int8_bytes =
                write_mdwb_i8(&dir.join(&int8_file), path, &shape, &q, &scale)?;
            weights_json.push_str(&format!(
                ", \"int8\": {{\"file\": \"{int8_file}\", \"bytes\": {int8_bytes}}}"
            ));
        }
        weights_json.push('}');

        let acts: Vec<String> = comp
            .activations
            .iter()
            .map(|(shape, dtype)| {
                format!(
                    "{{\"shape\": {}, \"dtype\": \"{dtype}\"}}",
                    fmt_usize_arr(shape)
                )
            })
            .collect();
        let outs: Vec<String> = comp
            .outputs
            .iter()
            .map(|shape| {
                format!(
                    "{{\"shape\": {}, \"dtype\": \"float32\"}}",
                    fmt_usize_arr(shape)
                )
            })
            .collect();
        comp_json.push(format!(
            concat!(
                "\"{name}\": {{\n",
                "  \"hlo\": \"{hlo}\", \"variant\": \"{variant}\",\n",
                "  \"params\": [{{\"path\": \"{path}\", \"shape\": {shape}, ",
                "\"dtype\": \"float32\"}}],\n",
                "  \"activations\": [{acts}],\n",
                "  \"outputs\": [{outs}],\n",
                "  \"param_bytes_f32\": {pb},\n",
                "  \"weights\": {weights}\n",
                "}}"
            ),
            name = comp.name,
            hlo = hlo_file,
            variant = comp.variant,
            path = path,
            shape = fmt_usize_arr(&shape),
            acts = acts.join(", "),
            outs = outs.join(", "),
            pb = comp.weight_elems * 4,
            weights = weights_json,
        ));
    }

    let params = SchedulerParams {
        num_train_timesteps: spec.num_train_timesteps,
        ..Default::default()
    };
    let ddim = Ddim::new(params.clone());
    let alphas: Vec<String> = ddim
        .alphas_cumprod
        .iter()
        .map(|a| format!("{a:.15}"))
        .collect();
    let timesteps: Vec<String> = ddim
        .timesteps(params.num_inference_steps)
        .iter()
        .map(|t| t.to_string())
        .collect();

    let manifest = format!(
        concat!(
            "{{\n",
            "\"cfg_batch\": 2,\n",
            "\"latent\": {{\"size\": {s}, \"channels\": {c}}},\n",
            "\"image\": {{\"size\": {img}, \"channels\": 3}},\n",
            "\"components\": {{\n{comps}\n}},\n",
            "\"scheduler\": {{\n",
            "  \"num_train_timesteps\": {ntt}, \"beta_start\": {bs:.5},\n",
            "  \"beta_end\": {be:.5}, \"num_inference_steps\": {nis},\n",
            "  \"guidance_scale\": {gs:.1},\n",
            "  \"alphas_cumprod\": [{alphas}],\n",
            "  \"timesteps\": [{timesteps}],\n",
            "  \"golden\": {{\"latent0\": [], \"eps_scale\": 0.1, \"trace\": [], ",
            "\"multistep_trace\": []}}\n",
            "}},\n",
            "\"tokenizer\": {{\"vocab_size\": {vocab}, \"seq_len\": {seq}, ",
            "\"golden\": []}}\n",
            "}}\n"
        ),
        s = s,
        c = c,
        img = img,
        comps = comp_json.join(",\n"),
        ntt = params.num_train_timesteps,
        bs = params.beta_start,
        be = params.beta_end,
        nis = params.num_inference_steps,
        gs = params.guidance_scale,
        alphas = alphas.join(", "),
        timesteps = timesteps.join(", "),
        vocab = spec.vocab_size,
        seq = seq,
    );
    std::fs::write(dir.join("manifest.json"), manifest)
        .map_err(|e| Error::Io(format!("manifest.json: {e}")))?;
    Ok(())
}

/// Write the artifacts under the system temp dir, keyed by `label`
/// (tests use distinct labels so parallel tests never share a dir).
pub fn fake_artifacts_dir(label: &str, spec: &FakeArtifactSpec) -> Result<PathBuf> {
    let dir = std::env::temp_dir().join(format!("md_testart_{label}"));
    write_fake_artifacts(&dir, spec)?;
    Ok(dir)
}

/// Minimal MDWB writer (one f32 tensor) mirroring the layout of
/// python/compile/weightsbin.py; returns the at-rest byte count the
/// manifest's `bytes` field must carry.
fn write_mdwb_f32(
    file: &Path,
    tensor_path: &str,
    shape: &[usize],
    values: &[f32],
) -> Result<usize> {
    let mut out: Vec<u8> = Vec::with_capacity(32 + values.len() * 4);
    out.extend_from_slice(b"MDWB");
    out.extend_from_slice(&1u32.to_le_bytes()); // version
    out.extend_from_slice(&1u32.to_le_bytes()); // tensor count
    out.extend_from_slice(&(tensor_path.len() as u16).to_le_bytes());
    out.extend_from_slice(tensor_path.as_bytes());
    out.push(0); // dtype f32
    out.push(shape.len() as u8);
    for &d in shape {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    for v in values {
        out.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(file, &out).map_err(|e| Error::Io(format!("{}: {e}", file.display())))?;
    Ok(values.len() * 4)
}

/// Minimal MDWB writer for one per-channel int8 tensor (keep mask all
/// ones — quantized but unpruned), mirroring weightsbin.py's layout;
/// returns the at-rest byte count for the manifest's `bytes` field.
fn write_mdwb_i8(
    file: &Path,
    tensor_path: &str,
    shape: &[usize],
    q: &[i8],
    scale: &[f32],
) -> Result<usize> {
    let cout = scale.len();
    let mut out: Vec<u8> = Vec::with_capacity(32 + q.len() + cout * 5);
    out.extend_from_slice(b"MDWB");
    out.extend_from_slice(&1u32.to_le_bytes()); // version
    out.extend_from_slice(&1u32.to_le_bytes()); // tensor count
    out.extend_from_slice(&(tensor_path.len() as u16).to_le_bytes());
    out.extend_from_slice(tensor_path.as_bytes());
    out.push(1); // dtype int8
    out.push(shape.len() as u8);
    for &d in shape {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    for s in scale {
        out.extend_from_slice(&s.to_le_bytes());
    }
    out.extend(std::iter::repeat(1u8).take(cout)); // keep mask: no pruning
    out.extend(q.iter().map(|&v| v as u8));
    std::fs::write(file, &out).map_err(|e| Error::Io(format!("{}: {e}", file.display())))?;
    Ok(q.len() + cout * 4 + cout)
}

fn fmt_usize_arr(v: &[usize]) -> String {
    let items: Vec<String> = v.iter().map(|x| x.to_string()).collect();
    format!("[{}]", items.join(", "))
}

/// Pool-driving throughput harness shared by `benches/throughput.rs`
/// and the tier-1 smoke test.
pub mod throughput {
    use std::path::Path;
    use std::time::{Duration, Instant};

    use super::{fake_artifacts_dir, FakeArtifactSpec};
    use crate::config::AppConfig;
    use crate::coordinator::{Server, SubmitOptions};
    use crate::error::{Error, Result};
    use crate::util::rng::Rng;
    use crate::util::stats::summarize;

    /// One measured operating point.
    #[derive(Debug, Clone)]
    pub struct Row {
        pub batch: usize,
        pub requests: usize,
        pub steps: usize,
        pub wall_s: f64,
        pub images_per_s: f64,
        pub steps_per_s: f64,
        pub p95_latency_s: f64,
        pub mean_occupancy: f64,
    }

    /// Workload sizing.  `fast` is the CI smoke mode.
    #[derive(Debug, Clone)]
    pub struct Workload {
        pub requests: usize,
        pub steps: usize,
        pub spec: FakeArtifactSpec,
    }

    impl Workload {
        pub fn new(fast: bool) -> Workload {
            Workload {
                requests: if fast { 8 } else { 24 },
                steps: if fast { 6 } else { 8 },
                // the UNet weight digest is the per-dispatch fixed cost
                // batching amortizes; keep it dominant over per-row work
                // so the B=4-vs-B=1 gap dwarfs timer noise
                spec: FakeArtifactSpec {
                    unet_weight_elems: if fast { 131_072 } else { 262_144 },
                    ..Default::default()
                },
            }
        }
    }

    /// Drive a 1-worker pool at `max_batch` over `artifacts`, all
    /// requests submitted up front (the heavy-traffic shape).
    pub fn run_at(artifacts: &Path, wl: &Workload, max_batch: usize) -> Result<Row> {
        let mut cfg = AppConfig::default();
        cfg.artifacts_dir = artifacts.to_path_buf();
        cfg.num_workers = 1;
        cfg.queue_depth = wl.requests.max(1) * 2;
        cfg.max_batch = max_batch;
        cfg.num_steps = wl.steps;
        let mut server = Server::start(&cfg)?;

        let t0 = Instant::now();
        let mut pending = Vec::with_capacity(wl.requests);
        for i in 0..wl.requests {
            pending.push(server.submit(&format!("prompt {i}"), i as u64)?);
        }
        let mut latencies = Vec::with_capacity(wl.requests);
        for rx in pending {
            let resp = rx
                .recv()
                .map_err(|_| Error::Runtime("worker dropped request".into()))??;
            debug_assert_eq!(resp.timings.denoise_steps, wl.steps);
            latencies.push(t0.elapsed().as_secs_f64());
        }
        let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
        // continuous sessions report rows-per-denoise-second (membership
        // changes mid-flight, so formation-time occupancy undercounts a
        // session that filled up via joins); run-to-completion pools
        // only have the formation-time mean
        let occupancy = server.with_metrics(|m| {
            let tw = m.time_weighted_occupancy();
            if tw > 0.0 {
                tw
            } else {
                m.mean_batch_occupancy()
            }
        });
        Ok(Row {
            batch: max_batch,
            requests: wl.requests,
            steps: wl.steps,
            wall_s,
            images_per_s: wl.requests as f64 / wall_s,
            steps_per_s: (wl.requests * wl.steps) as f64 / wall_s,
            p95_latency_s: summarize(&latencies).p95,
            mean_occupancy: occupancy,
        })
    }

    /// Run the batch-size sweep on fresh fake artifacts.
    pub fn run_profile(label: &str, wl: &Workload, batches: &[usize]) -> Result<Vec<Row>> {
        let dir = fake_artifacts_dir(label, &wl.spec)?;
        batches.iter().map(|&b| run_at(&dir, wl, b)).collect()
    }

    /// One open-loop (Poisson arrivals) operating point for one
    /// scheduling mode.
    #[derive(Debug, Clone)]
    pub struct OpenLoopRow {
        /// step-level continuous batching vs run-to-completion
        pub continuous: bool,
        /// offered arrival rate (requests/s)
        pub lambda_rps: f64,
        /// offered load relative to the solo service rate
        pub load_factor: f64,
        pub requests: usize,
        pub wall_s: f64,
        pub p50_latency_s: f64,
        pub p95_latency_s: f64,
        pub p99_latency_s: f64,
        pub mean_occupancy: f64,
        pub joins: usize,
        pub preemptions: usize,
    }

    /// Drive a 1-worker pool with *open-loop* Poisson arrivals at
    /// `lambda_rps`: requests arrive on a schedule the server does not
    /// control (deterministic exponential gaps from `seed`, so the
    /// continuous and run-to-completion runs see identical traffic),
    /// and each request's latency is measured the moment it completes.
    /// Step schedules alternate short/long so an in-flight batch always
    /// has straggler slots worth reclaiming.
    pub fn run_open_loop(
        artifacts: &Path,
        wl: &Workload,
        max_batch: usize,
        lambda_rps: f64,
        continuous: bool,
        seed: u64,
    ) -> Result<OpenLoopRow> {
        let mut cfg = AppConfig::default();
        cfg.artifacts_dir = artifacts.to_path_buf();
        cfg.num_workers = 1;
        cfg.queue_depth = wl.requests.max(1) * 2;
        cfg.max_batch = max_batch;
        cfg.num_steps = wl.steps;
        cfg.continuous = continuous;
        let mut server = Server::start(&cfg)?;

        let mut rng = Rng::new(seed);
        let gaps: Vec<f64> = (0..wl.requests)
            .map(|_| {
                let u = rng.next_f64();
                -(1.0 - u).ln() / lambda_rps.max(1e-9)
            })
            .collect();
        let short = (wl.steps / 2).max(2);
        let long = wl.steps * 2;

        let t0 = Instant::now();
        let mut collectors = Vec::with_capacity(wl.requests);
        let mut due_s = 0.0f64;
        for (i, gap) in gaps.iter().enumerate() {
            due_s += gap;
            let due = t0 + Duration::from_secs_f64(due_s);
            let now = Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
            let opts = SubmitOptions {
                num_steps: Some(if i % 2 == 0 { short } else { long }),
                ..Default::default()
            };
            let arrival = Instant::now();
            let rx = server.submit_with(&format!("open {i}"), i as u64, opts)?;
            // per-request collector so completion is observed when it
            // happens, not when an earlier channel unblocks
            collectors.push(std::thread::spawn(move || -> Result<f64> {
                rx.recv()
                    .map_err(|_| Error::Runtime("worker dropped request".into()))??;
                Ok(arrival.elapsed().as_secs_f64())
            }));
        }
        let mut latencies = Vec::with_capacity(collectors.len());
        for c in collectors {
            let lat = c
                .join()
                .map_err(|_| Error::Runtime("latency collector panicked".into()))??;
            latencies.push(lat);
        }
        let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
        let s = summarize(&latencies);
        let (occupancy, joins, preemptions) = server.with_metrics(|m| {
            let tw = m.time_weighted_occupancy();
            let occ = if tw > 0.0 { tw } else { m.mean_batch_occupancy() };
            (occ, m.joins, m.preemptions)
        });
        Ok(OpenLoopRow {
            continuous,
            lambda_rps,
            load_factor: 0.0, // filled by the sweep
            requests: wl.requests,
            wall_s,
            p50_latency_s: s.p50,
            p95_latency_s: s.p95,
            p99_latency_s: s.p99,
            mean_occupancy: occupancy,
            joins,
            preemptions,
        })
    }

    /// Offered-load sweep, continuous vs run-to-completion on identical
    /// arrival schedules.  The load unit is calibrated from a solo run:
    /// `load_factor = 1.0` offers one request per measured solo service
    /// time, so factors > 1 oversubscribe a run-to-completion worker.
    pub fn run_open_loop_profile(
        label: &str,
        wl: &Workload,
        max_batch: usize,
        load_factors: &[f64],
    ) -> Result<Vec<OpenLoopRow>> {
        let dir = fake_artifacts_dir(label, &wl.spec)?;
        let calib = Workload { requests: 2, ..wl.clone() };
        let solo = run_at(&dir, &calib, 1)?;
        let service_s = (solo.wall_s / calib.requests as f64).max(1e-6);
        let mut rows = Vec::new();
        for (k, &f) in load_factors.iter().enumerate() {
            let lambda = f / service_s;
            for continuous in [false, true] {
                let mut row =
                    run_open_loop(&dir, wl, max_batch, lambda, continuous, 42 + k as u64)?;
                row.load_factor = f;
                rows.push(row);
            }
        }
        Ok(rows)
    }

    /// Serialize closed-loop rows plus the open-loop sweep as the
    /// BENCH_throughput.json payload (a superset of [`to_json`]'s).
    pub fn to_json_with_open_loop(
        rows: &[Row],
        open: &[OpenLoopRow],
        fast: bool,
    ) -> String {
        let closed = to_json(rows, fast);
        let body: Vec<String> = open
            .iter()
            .map(|r| {
                format!(
                    concat!(
                        "  {{\"continuous\": {}, \"lambda_rps\": {:.3}, ",
                        "\"load_factor\": {:.3}, \"requests\": {}, ",
                        "\"wall_s\": {:.6}, \"p50_latency_s\": {:.6}, ",
                        "\"p95_latency_s\": {:.6}, \"p99_latency_s\": {:.6}, ",
                        "\"mean_occupancy\": {:.3}, \"joins\": {}, ",
                        "\"preemptions\": {}}}"
                    ),
                    r.continuous,
                    r.lambda_rps,
                    r.load_factor,
                    r.requests,
                    r.wall_s,
                    r.p50_latency_s,
                    r.p95_latency_s,
                    r.p99_latency_s,
                    r.mean_occupancy,
                    r.joins,
                    r.preemptions,
                )
            })
            .collect();
        let open_json = format!(",\n\"open_loop\": [\n{}\n]\n}}\n", body.join(",\n"));
        // splice the open-loop section before the closing brace
        let trimmed = closed.trim_end();
        let without_close = trimmed.strip_suffix('}').unwrap_or(trimmed);
        format!("{}{}", without_close.trim_end().trim_end_matches('\n'), open_json)
    }

    /// Serialize rows as the BENCH_throughput.json payload.
    pub fn to_json(rows: &[Row], fast: bool) -> String {
        let body: Vec<String> = rows
            .iter()
            .map(|r| {
                format!(
                    concat!(
                        "  {{\"batch\": {}, \"requests\": {}, \"steps\": {}, ",
                        "\"wall_s\": {:.6}, \"images_per_s\": {:.3}, ",
                        "\"steps_per_s\": {:.3}, \"p95_latency_s\": {:.6}, ",
                        "\"mean_occupancy\": {:.3}}}"
                    ),
                    r.batch,
                    r.requests,
                    r.steps,
                    r.wall_s,
                    r.images_per_s,
                    r.steps_per_s,
                    r.p95_latency_s,
                    r.mean_occupancy,
                )
            })
            .collect();
        format!(
            "{{\n\"backend\": \"xla-stub\",\n\"fast\": {fast},\n\"rows\": [\n{}\n]\n}}\n",
            body.join(",\n")
        )
    }
}
