//! Pass manager: runs the paper's rewrites in order and verifies the
//! delegation invariants afterwards.

use crate::delegate::{DeviceProfile, RuleSet, GPU_ADRENO740};
use crate::graph::Graph;

use super::fc_to_conv::FcToConv;
use super::gelu::StableGelu;
use super::groupnorm::GroupNormRewrite;
use super::serialize_conv::SerializeConv;
use super::Pass;

#[derive(Debug, Clone, Default)]
pub struct PassReport {
    /// (pass name, sites rewritten)
    pub applied: Vec<(&'static str, usize)>,
    pub coverage_before: f64,
    pub coverage_after: f64,
    pub ops_before: usize,
    pub ops_after: usize,
}

impl PassReport {
    pub fn total_rewrites(&self) -> usize {
        self.applied.iter().map(|(_, n)| n).sum()
    }
}

/// Which of the paper's techniques to apply (ablation switch).
#[derive(Debug, Clone, Copy)]
pub struct PassConfig {
    pub fc_to_conv: bool,
    pub groupnorm: bool,
    pub serialize_conv: bool,
    pub stable_gelu: bool,
}

impl Default for PassConfig {
    fn default() -> Self {
        PassConfig {
            fc_to_conv: true,
            groupnorm: true,
            serialize_conv: true,
            stable_gelu: true,
        }
    }
}

impl PassConfig {
    pub const NONE: PassConfig = PassConfig {
        fc_to_conv: false,
        groupnorm: false,
        serialize_conv: false,
        stable_gelu: false,
    };
}

/// Run the configured passes.  Order matters and mirrors the paper:
/// group-norm rewrite first (removes the rank-5/BroadcastTo islands),
/// then FC->Conv, then conv serialization (which must see the final conv
/// set, including the ones FC conversion created), then the GELU clamp
/// (pure numerics, no delegation effect).
pub fn run_with_config(
    g: &mut Graph,
    rules: &RuleSet,
    dev: &DeviceProfile,
    cfg: PassConfig,
) -> PassReport {
    let mut report = PassReport {
        coverage_before: rules.coverage(g),
        ops_before: g.ops.len(),
        ..Default::default()
    };

    if cfg.groupnorm {
        let p = GroupNormRewrite;
        let n = p.run(g);
        report.applied.push((p.name(), n));
    }
    if cfg.fc_to_conv {
        let p = FcToConv { only_failing: false, rules: rules.clone() };
        let n = p.run(g);
        report.applied.push((p.name(), n));
    }
    if cfg.serialize_conv {
        let p = SerializeConv {
            rules: rules.clone(),
            dev: dev.clone(),
            force_dim: None,
        };
        let n = p.run(g);
        report.applied.push((p.name(), n));
    }
    if cfg.stable_gelu {
        let p = StableGelu::default();
        let n = p.run(g);
        report.applied.push((p.name(), n));
    }

    debug_assert!(g.validate().is_ok());
    report.coverage_after = rules.coverage(g);
    report.ops_after = g.ops.len();
    report
}

/// All passes with the default device/rules.
pub fn run_all(g: &mut Graph) -> PassReport {
    run_all_for(g, &GPU_ADRENO740)
}

/// All passes with the default rules on an explicit delegate profile —
/// the `--device` CLI path and the planner's per-class trials.
pub fn run_all_for(g: &mut Graph, dev: &DeviceProfile) -> PassReport {
    run_with_config(g, &RuleSet::default(), dev, PassConfig::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::OpType;

    /// A miniature SD-flavored graph with every pathology at once.
    fn pathological() -> Graph {
        let mut b = GraphBuilder::new("patho");
        let x = b.input("x", &[1, 32, 32, 1920]);
        let y = b.group_norm_naive("gn", x, 32);
        let y = b.conv2d("big", y, 640, 3, 1);
        let flat = b.reshape("flatten", y, &[1, 4096 / 4, 640 * 4]);
        let flat = b.reshape("flatten2", flat, &[1, 4096, 640]);
        let h = b.fully_connected("ff1", flat, 2560);
        let h = b.gelu("gelu", h, false);
        b.fully_connected("ff2", h, 640);
        b.finish()
    }

    #[test]
    fn full_pipeline_reaches_complete_delegation() {
        let mut g = pathological();
        let rules = RuleSet::default();
        assert!(rules.coverage(&g) < 1.0);

        let report = run_all(&mut g);
        g.validate().unwrap();
        assert_eq!(report.coverage_after, 1.0, "complete delegation");
        assert!(report.coverage_before < report.coverage_after);
        assert!(report.total_rewrites() >= 4);
        assert_eq!(g.op_histogram().get(&OpType::BroadcastTo), None);
        assert!(g.max_rank() <= 4);
    }

    #[test]
    fn ablation_without_serialization_leaves_conv_failing() {
        let mut g = pathological();
        let rules = RuleSet::default();
        let cfg = PassConfig { serialize_conv: false, ..Default::default() };
        run_with_config(&mut g, &rules, &GPU_ADRENO740, cfg);
        let fails = rules.failures(&g);
        assert!(fails.iter().any(|(op, _)| op.ty == OpType::Conv2d));
    }

    #[test]
    fn ablation_none_is_identity_coverage() {
        let mut g = pathological();
        let rules = RuleSet::default();
        let before = rules.coverage(&g);
        let r = run_with_config(&mut g, &rules, &GPU_ADRENO740, PassConfig::NONE);
        assert_eq!(r.coverage_before, before);
        assert_eq!(r.coverage_after, before);
        assert_eq!(r.total_rewrites(), 0);
    }

    #[test]
    fn property_passes_preserve_validity_on_random_graphs() {
        use crate::graph::builder::random_graph;
        use crate::util::rng::Rng;
        for seed in 0..30 {
            let mut rng = Rng::new(seed + 1000);
            let mut g = random_graph(&mut rng, 20);
            let before_outputs: Vec<Vec<usize>> = g
                .ops
                .iter()
                .map(|o| o.outputs.iter().map(|&t| g.tensor(t).elems()).collect())
                .collect();
            let _ = before_outputs;
            run_all(&mut g);
            g.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            assert_eq!(
                g.op_histogram().get(&OpType::BroadcastTo),
                None,
                "seed {seed}"
            );
            assert!(g.max_rank() <= 4, "seed {seed}");
        }
    }
}
