//! Pass manager: runs a [`PassRegistry`]'s rewrites in order and
//! verifies the delegation invariants afterwards.
//!
//! The registry is the single pipeline definition — the planner's
//! cost-gated trials (`planner::plan::plan_graph`) iterate the same
//! [`PassRegistry::standard`] list, so offline CLI runs and online
//! planning can never disagree about pass order.  Ablations run a
//! [`PassRegistry::subset`]/[`PassRegistry::without`] of the standard
//! registry instead of toggling config bools.

use crate::delegate::{DeviceProfile, RuleSet, GPU_ADRENO740};
use crate::graph::Graph;

use super::registry::PassRegistry;

#[derive(Debug, Clone, Default)]
pub struct PassReport {
    /// (pass report label, sites rewritten), in run order
    pub applied: Vec<(&'static str, usize)>,
    pub coverage_before: f64,
    pub coverage_after: f64,
    pub ops_before: usize,
    pub ops_after: usize,
}

impl PassReport {
    pub fn total_rewrites(&self) -> usize {
        self.applied.iter().map(|(_, n)| n).sum()
    }
}

/// Run every pass in `registry`, in registry order, against the
/// delegate `rules` and device profile `dev`.
pub fn run_registry(
    g: &mut Graph,
    rules: &RuleSet,
    dev: &DeviceProfile,
    registry: &PassRegistry,
) -> PassReport {
    let mut report = PassReport {
        coverage_before: rules.coverage(g),
        ops_before: g.ops.len(),
        ..Default::default()
    };

    for spec in registry.specs() {
        let pass = spec.build(rules, dev);
        let n = pass.run(g);
        report.applied.push((pass.name(), n));
    }

    debug_assert!(g.validate().is_ok());
    report.coverage_after = rules.coverage(g);
    report.ops_after = g.ops.len();
    report
}

/// The standard registry with the default device/rules.
pub fn run_all(g: &mut Graph) -> PassReport {
    run_all_for(g, &GPU_ADRENO740)
}

/// The standard registry with the default rules on an explicit delegate
/// profile — the `--device` CLI path and the planner's per-class trials.
pub fn run_all_for(g: &mut Graph, dev: &DeviceProfile) -> PassReport {
    run_registry(g, &RuleSet::default(), dev, &PassRegistry::standard())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::OpType;

    /// A miniature SD-flavored graph with every pathology at once.
    fn pathological() -> Graph {
        let mut b = GraphBuilder::new("patho");
        let x = b.input("x", &[1, 32, 32, 1920]);
        let y = b.group_norm_naive("gn", x, 32);
        let y = b.conv2d("big", y, 640, 3, 1);
        let flat = b.reshape("flatten", y, &[1, 4096 / 4, 640 * 4]);
        let flat = b.reshape("flatten2", flat, &[1, 4096, 640]);
        let h = b.fully_connected("ff1", flat, 2560);
        let h = b.gelu("gelu", h, false);
        b.fully_connected("ff2", h, 640);
        b.finish()
    }

    #[test]
    fn full_pipeline_reaches_complete_delegation() {
        let mut g = pathological();
        let rules = RuleSet::default();
        assert!(rules.coverage(&g) < 1.0);

        let report = run_all(&mut g);
        g.validate().unwrap();
        assert_eq!(report.coverage_after, 1.0, "complete delegation");
        assert!(report.coverage_before < report.coverage_after);
        assert!(report.total_rewrites() >= 4);
        assert_eq!(g.op_histogram().get(&OpType::BroadcastTo), None);
        assert!(g.max_rank() <= 4);
        // the report lists every registered pass, in registry order
        assert_eq!(report.applied.len(), PassRegistry::standard().len());
    }

    #[test]
    fn ablation_without_serialization_leaves_conv_failing() {
        let mut g = pathological();
        let rules = RuleSet::default();
        let reg = PassRegistry::standard().without(&["serialize_conv"]);
        run_registry(&mut g, &rules, &GPU_ADRENO740, &reg);
        let fails = rules.failures(&g);
        assert!(fails.iter().any(|(op, _)| op.ty == OpType::Conv2d));
    }

    #[test]
    fn ablation_empty_registry_is_identity_coverage() {
        let mut g = pathological();
        let rules = RuleSet::default();
        let before = rules.coverage(&g);
        let r = run_registry(&mut g, &rules, &GPU_ADRENO740, &PassRegistry::empty());
        assert_eq!(r.coverage_before, before);
        assert_eq!(r.coverage_after, before);
        assert_eq!(r.total_rewrites(), 0);
        assert!(r.applied.is_empty());
    }

    #[test]
    fn property_passes_preserve_validity_on_random_graphs() {
        use crate::graph::builder::random_graph;
        use crate::util::rng::Rng;
        for seed in 0..30 {
            let mut rng = Rng::new(seed + 1000);
            let mut g = random_graph(&mut rng, 20);
            run_all(&mut g);
            g.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            assert_eq!(
                g.op_histogram().get(&OpType::BroadcastTo),
                None,
                "seed {seed}"
            );
            assert!(g.max_rank() <= 4, "seed {seed}");
        }
    }
}
