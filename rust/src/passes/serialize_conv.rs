//! Conv2D serialization (paper Fig. 1b + the minimal-factor search).
//!
//! For every k>1 conv the delegate rejects, search the minimal
//! serialization factor — trying factors in increasing order along the
//! input-channel dimension and the output-channel dimension, exactly as
//! the paper describes — then pick the dimension with the lower modeled
//! latency (the paper measured 15.5 ms input vs 40.9 ms output and chose
//! input).  The chosen conv is rewritten into `factor` StridedSlice +
//! Conv2D calls combined with Adds (input) or a Concatenation (output).
//!
//! Pattern: a `CONV_2D` anchor with a k>1 kernel the delegate rejects.
//! The factor search is the rewrite callback's job — a site with no
//! workable factor is *rejected* (the callback returns `false`), which
//! the old hand-rolled traversal expressed as a `continue`.

use std::collections::BTreeMap;

use crate::delegate::{cost, DeviceProfile, RuleSet, GPU_ADRENO740};
use crate::graph::pattern::{self, Pattern, PatternNode};
use crate::graph::{DType, Graph, Op, OpType, TensorId};

use super::Pass;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dim {
    Input,
    Output,
}

#[derive(Debug, Clone)]
pub struct SerializationPlan {
    pub dim: Dim,
    pub factor: usize,
    pub latency: f64,
}

/// Find the minimal factor along `dim` for which every per-call slice of
/// the conv is delegable; factors are divisors of the channel count
/// tried in increasing order (paper: "trying possible serialization
/// factors in increasing order along each dimension").
pub fn minimal_factor(
    rules: &RuleSet,
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    k: usize,
    dim: Dim,
) -> Option<usize> {
    let channels = match dim {
        Dim::Input => cin,
        Dim::Output => cout,
    };
    for factor in 2..=channels {
        if channels % factor != 0 {
            continue;
        }
        let (ci, co) = match dim {
            Dim::Input => (cin / factor, cout),
            Dim::Output => (cin, cout / factor),
        };
        if conv_slice_delegable(rules, h, w, ci, co, k) {
            return Some(factor);
        }
    }
    None
}

fn conv_slice_delegable(
    rules: &RuleSet,
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    k: usize,
) -> bool {
    let mut g = Graph::new("probe");
    let x = g.add_tensor("x", &[1, h, w, cin], DType::F16, false);
    let wt = g.add_tensor("w", &[k, k, cin, cout], DType::F16, true);
    let y = g.add_tensor("y", &[1, h, w, cout], DType::F16, false);
    let mut attrs = BTreeMap::new();
    attrs.insert("kernel".into(), k as f64);
    let id = g.add_op_with_attrs(OpType::Conv2d, "c", vec![x, wt], vec![y], attrs);
    rules.check(&g, &g.ops[id]).ok()
}

/// The paper's decision procedure: minimal factor along each dimension,
/// modeled latency for each, pick the cheaper.
pub fn plan(
    rules: &RuleSet,
    dev: &DeviceProfile,
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    k: usize,
) -> Option<SerializationPlan> {
    let mut best: Option<SerializationPlan> = None;
    for dim in [Dim::Input, Dim::Output] {
        if let Some(factor) = minimal_factor(rules, h, w, cin, cout, k, dim) {
            let latency = cost::serialized_conv_latency(
                h,
                w,
                cin,
                cout,
                k,
                factor,
                dim == Dim::Input,
                dev,
            );
            if best.as_ref().map(|b| latency < b.latency).unwrap_or(true) {
                best = Some(SerializationPlan { dim, factor, latency });
            }
        }
    }
    best
}

pub struct SerializeConv {
    pub rules: RuleSet,
    pub dev: DeviceProfile,
    /// force a dimension instead of picking by latency (ablation)
    pub force_dim: Option<Dim>,
}

impl Default for SerializeConv {
    fn default() -> Self {
        SerializeConv { rules: RuleSet::default(), dev: GPU_ADRENO740, force_dim: None }
    }
}

impl Pass for SerializeConv {
    fn name(&self) -> &'static str {
        "serialize-conv"
    }

    fn run(&self, g: &mut Graph) -> usize {
        let rules = self.rules.clone();
        let pat = Pattern::new(PatternNode::op(OpType::Conv2d).pred(move |ctx, op| {
            op.attr_i("kernel").unwrap_or(1) > 1 && !rules.check(ctx.graph, op).ok()
        }));
        pattern::apply(g, self.name(), &pat, |g, m| self.rewrite_site(g, m.anchor))
    }
}

impl SerializeConv {
    /// Search the minimal factor for the conv at `op_id` and rewrite it;
    /// `false` (site rejected) when no workable factor exists.
    fn rewrite_site(&self, g: &mut Graph, op_id: usize) -> bool {
        let (x_id, out_id, name, k) = {
            let op = g.ops.iter().find(|o| o.id == op_id).unwrap();
            let x = *op
                .inputs
                .iter()
                .find(|&&t| !g.tensor(t).is_const)
                .expect("conv input");
            (x, op.outputs[0], op.name.clone(), op.attr_i("kernel").unwrap() as usize)
        };
        let xs = g.tensor(x_id).shape.clone();
        let os = g.tensor(out_id).shape.clone();
        let (h, w, cin) = (xs[1], xs[2], xs[3]);
        let cout = os[3];

        let mut p = match plan(&self.rules, &self.dev, h, w, cin, cout, k) {
            Some(p) => p,
            None => return false,
        };
        if let Some(d) = self.force_dim {
            if let Some(f) = minimal_factor(&self.rules, h, w, cin, cout, k, d) {
                p = SerializationPlan { dim: d, factor: f, latency: p.latency };
            } else {
                return false;
            }
        }

        match p.dim {
            Dim::Input => rewrite_input(g, op_id, x_id, out_id, &name, k, p.factor),
            Dim::Output => rewrite_output(g, op_id, x_id, out_id, &name, k, p.factor),
        }
        true
    }
}

fn conv_attrs(k: usize, factor: usize, dim: &str) -> BTreeMap<String, f64> {
    let mut attrs = BTreeMap::new();
    attrs.insert("kernel".into(), k as f64);
    attrs.insert("stride".into(), 1.0);
    attrs.insert("serialized".into(), factor as f64);
    attrs.insert(format!("serial_{dim}"), 1.0);
    attrs
}

/// Replace the op at `op_id` with: factor x (StridedSlice + Conv2D) and a
/// tree of Adds producing `out_id` (input-channel serialization).
fn rewrite_input(
    g: &mut Graph,
    op_id: usize,
    x_id: TensorId,
    out_id: TensorId,
    name: &str,
    k: usize,
    factor: usize,
) {
    let xs = g.tensor(x_id).shape.clone();
    let os = g.tensor(out_id).shape.clone();
    let dt = g.tensor(x_id).dtype;
    let (n, h, w, cin) = (xs[0], xs[1], xs[2], xs[3]);
    let cg = cin / factor;

    let mut new_ops: Vec<Op> = Vec::new();
    let mut partials: Vec<TensorId> = Vec::new();
    for i in 0..factor {
        let slice = g.add_tensor(&format!("{name}/in_slice{i}"), &[n, h, w, cg], dt, false);
        new_ops.push(Op {
            id: usize::MAX,
            ty: OpType::StridedSlice,
            name: format!("{name}/slice{i}"),
            inputs: vec![x_id],
            outputs: vec![slice],
            attrs: {
                let mut a = BTreeMap::new();
                a.insert("begin".into(), (i * cg) as f64);
                a.insert("size".into(), cg as f64);
                a.insert("axis".into(), 3.0);
                a
            },
        });
        let wt = g.add_tensor(
            &format!("{name}/w_slice{i}"),
            &[k, k, cg, os[3]],
            DType::F32,
            true,
        );
        let part = g.add_tensor(&format!("{name}/part{i}"), &os, dt, false);
        new_ops.push(Op {
            id: usize::MAX,
            ty: OpType::Conv2d,
            name: format!("{name}/conv{i}"),
            inputs: vec![slice, wt],
            outputs: vec![part],
            attrs: conv_attrs(k, factor, "input"),
        });
        partials.push(part);
    }
    // accumulate partial sums; the last add writes the original output
    let mut acc = partials[0];
    for (i, &p) in partials.iter().enumerate().skip(1) {
        let dst = if i == factor - 1 {
            out_id
        } else {
            g.add_tensor(&format!("{name}/acc{i}"), &os, dt, false)
        };
        new_ops.push(Op {
            id: usize::MAX,
            ty: OpType::Add,
            name: format!("{name}/acc_add{i}"),
            inputs: vec![acc, p],
            outputs: vec![dst],
            attrs: BTreeMap::new(),
        });
        acc = dst;
    }

    let pos = g.ops.iter().position(|o| o.id == op_id).unwrap();
    g.ops.splice(pos..pos + 1, new_ops);
}

/// Output-channel serialization: factor Conv2Ds each producing a channel
/// slice, then one Concatenation into `out_id`.
fn rewrite_output(
    g: &mut Graph,
    op_id: usize,
    x_id: TensorId,
    out_id: TensorId,
    name: &str,
    k: usize,
    factor: usize,
) {
    let xs = g.tensor(x_id).shape.clone();
    let os = g.tensor(out_id).shape.clone();
    let dt = g.tensor(x_id).dtype;
    let cg = os[3] / factor;

    let mut new_ops: Vec<Op> = Vec::new();
    let mut parts: Vec<TensorId> = Vec::new();
    for i in 0..factor {
        let wt = g.add_tensor(
            &format!("{name}/w_oslice{i}"),
            &[k, k, xs[3], cg],
            DType::F32,
            true,
        );
        let part =
            g.add_tensor(&format!("{name}/opart{i}"), &[os[0], os[1], os[2], cg], dt, false);
        new_ops.push(Op {
            id: usize::MAX,
            ty: OpType::Conv2d,
            name: format!("{name}/oconv{i}"),
            inputs: vec![x_id, wt],
            outputs: vec![part],
            attrs: conv_attrs(k, factor, "output"),
        });
        parts.push(part);
    }
    new_ops.push(Op {
        id: usize::MAX,
        ty: OpType::Concatenation,
        name: format!("{name}/concat"),
        inputs: parts,
        outputs: vec![out_id],
        attrs: BTreeMap::new(),
    });

    let pos = g.ops.iter().position(|o| o.id == op_id).unwrap();
    g.ops.splice(pos..pos + 1, new_ops);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    #[test]
    fn minimal_factors_match_paper() {
        let rules = RuleSet::default();
        assert_eq!(
            minimal_factor(&rules, 32, 32, 1920, 640, 3, Dim::Input),
            Some(2)
        );
        assert_eq!(
            minimal_factor(&rules, 32, 32, 1920, 640, 3, Dim::Output),
            Some(8)
        );
    }

    #[test]
    fn plan_prefers_input_dimension() {
        let p = plan(&RuleSet::default(), &GPU_ADRENO740, 32, 32, 1920, 640, 3).unwrap();
        assert_eq!(p.dim, Dim::Input);
        assert_eq!(p.factor, 2);
    }

    #[test]
    fn pass_rewrites_failing_conv() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 32, 32, 1920]);
        b.conv2d("big", x, 640, 3, 1);
        let mut g = b.finish();
        let rules = RuleSet::default();
        assert_eq!(rules.failures(&g).len(), 1);

        let n = SerializeConv::default().run(&mut g);
        assert_eq!(n, 1);
        g.validate().unwrap();
        assert!(rules.failures(&g).is_empty(), "{:?}", rules.failures(&g));

        let hist = g.op_histogram();
        assert_eq!(hist[&OpType::Conv2d], 2); // factor 2
        assert_eq!(hist[&OpType::StridedSlice], 2);
        assert_eq!(hist[&OpType::Add], 1);
    }

    #[test]
    fn forced_output_dim() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 32, 32, 1920]);
        b.conv2d("big", x, 640, 3, 1);
        let mut g = b.finish();
        let pass = SerializeConv {
            force_dim: Some(Dim::Output),
            ..Default::default()
        };
        assert_eq!(pass.run(&mut g), 1);
        g.validate().unwrap();
        let hist = g.op_histogram();
        assert_eq!(hist[&OpType::Conv2d], 8); // factor 8
        assert_eq!(hist[&OpType::Concatenation], 1);
        assert!(RuleSet::default().failures(&g).is_empty());
    }

    #[test]
    fn leaves_delegable_convs_alone() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 16, 16, 64]);
        b.conv2d("ok", x, 64, 3, 1);
        let mut g = b.finish();
        assert_eq!(SerializeConv::default().run(&mut g), 0);
        assert_eq!(g.op_histogram()[&OpType::Conv2d], 1);
    }
}
