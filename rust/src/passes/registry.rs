//! The pass registry: the single source of truth for which rewrites
//! exist and the order they run in.
//!
//! Everything that used to hard-code the pipeline — the ablation
//! `PassConfig` bools, the planner's duplicated `pass_stages()` list,
//! the CLI's pass command — now derives from [`PassRegistry::standard`],
//! so the order can never drift between the offline pipeline and the
//! planner's cost-gated trials again.
//!
//! Order matters and mirrors the paper: group-norm rewrite first
//! (removes the rank-5/BroadcastTo islands), then FC->Conv, then conv
//! serialization (which must see the final conv set, including the
//! ones FC conversion created), then the GELU clamp (pure numerics).
//! The attention fusions run last: they only ever *remove* work, and
//! running them after the coverage passes means the cost gate judges
//! them on an already-delegable graph.
//!
//! Each [`PassSpec`] carries a registry name (stable, CLI- and
//! planner-facing: `fc_to_conv`) and a factory building the pass for a
//! `(RuleSet, DeviceProfile)` context.  The constructed pass's own
//! [`Pass::name`] is its report label (`fc-to-conv`), kept distinct so
//! `PassReport` output stays bit-identical with the seed pipeline.

use crate::delegate::{DeviceProfile, RuleSet};
use crate::error::{Error, Result};

use super::attention_reshape::AttentionReshapeElim;
use super::fc_to_conv::FcToConv;
use super::fused_softmax::FusedSoftmaxPass;
use super::gelu::StableGelu;
use super::groupnorm::GroupNormRewrite;
use super::serialize_conv::SerializeConv;
use super::Pass;

/// One registered rewrite: name, one-line summary, and the factory
/// closing over nothing (context arrives at build time).
#[derive(Clone, Copy)]
pub struct PassSpec {
    /// stable registry name (planner schedules, `--only`, docs)
    pub name: &'static str,
    /// one-line summary for `passes --list`
    pub summary: &'static str,
    factory: fn(&RuleSet, &DeviceProfile) -> Box<dyn Pass>,
}

impl PassSpec {
    /// Build the pass for a delegate-rules + device context.
    pub fn build(&self, rules: &RuleSet, dev: &DeviceProfile) -> Box<dyn Pass> {
        (self.factory)(rules, dev)
    }
}

impl std::fmt::Debug for PassSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PassSpec").field("name", &self.name).finish()
    }
}

/// An ordered list of passes; run order == list order.
#[derive(Debug, Clone)]
pub struct PassRegistry {
    specs: Vec<PassSpec>,
}

impl PassRegistry {
    /// The full shipped pipeline, in mandated order.
    pub fn standard() -> PassRegistry {
        PassRegistry {
            specs: vec![
                PassSpec {
                    name: "groupnorm",
                    summary: "broadcast-free group norm (Fig. 7): removes the \
                              rank-5/BroadcastTo CPU islands",
                    factory: |_, _| Box::new(GroupNormRewrite),
                },
                PassSpec {
                    name: "fc_to_conv",
                    summary: "FullyConnected -> 1x1 Conv2D (Fig. 1a): large FCs \
                              take the delegate's tiled matmul path",
                    factory: |rules, _| {
                        Box::new(FcToConv { only_failing: false, rules: rules.clone() })
                    },
                },
                PassSpec {
                    name: "serialize_conv",
                    summary: "over-capacity k>1 convs split into minimal-factor \
                              channel slices (Fig. 1b)",
                    factory: |rules, dev| {
                        Box::new(SerializeConv {
                            rules: rules.clone(),
                            dev: dev.clone(),
                            force_dim: None,
                        })
                    },
                },
                PassSpec {
                    name: "stable_gelu",
                    summary: "gamma_M clamp in front of the tanh-GELU cubic \
                              chain (Sec. 3.2, fp16 overflow)",
                    factory: |_, _| Box::new(StableGelu::default()),
                },
                PassSpec {
                    name: "fused_softmax",
                    summary: "exp/sum/div softmax island -> one memory-bound \
                              FUSED_SOFTMAX dispatch (arXiv 2304.11267)",
                    factory: |_, _| Box::new(FusedSoftmaxPass),
                },
                PassSpec {
                    name: "attention_reshape_elim",
                    summary: "cancelling Reshape/Transpose pairs around the \
                              attention matmuls removed (arXiv 2311.16567)",
                    factory: |_, _| Box::new(AttentionReshapeElim),
                },
            ],
        }
    }

    /// A registry with no passes (ablation baseline).
    pub fn empty() -> PassRegistry {
        PassRegistry { specs: Vec::new() }
    }

    pub fn specs(&self) -> &[PassSpec] {
        &self.specs
    }

    /// Registry names in run order.
    pub fn names(&self) -> Vec<&'static str> {
        self.specs.iter().map(|s| s.name).collect()
    }

    pub fn get(&self, name: &str) -> Option<&PassSpec> {
        self.specs.iter().find(|s| s.name == name)
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// Keep only the named passes.  Run order stays pipeline order
    /// regardless of the order names are given in; unknown names are a
    /// config error (the CLI `--only` path).
    pub fn subset(&self, names: &[&str]) -> Result<PassRegistry> {
        for n in names {
            if self.get(n).is_none() {
                return Err(Error::Config(format!(
                    "unknown pass '{n}' (known: {})",
                    self.names().join(", ")
                )));
            }
        }
        Ok(PassRegistry {
            specs: self
                .specs
                .iter()
                .filter(|s| names.contains(&s.name))
                .copied()
                .collect(),
        })
    }

    /// Drop the named passes (ablation convenience; unknown names are
    /// ignored).
    pub fn without(&self, names: &[&str]) -> PassRegistry {
        PassRegistry {
            specs: self
                .specs
                .iter()
                .filter(|s| !names.contains(&s.name))
                .copied()
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delegate::GPU_ADRENO740;

    #[test]
    fn standard_order_is_the_mandated_pipeline() {
        let reg = PassRegistry::standard();
        assert_eq!(
            reg.names(),
            vec![
                "groupnorm",
                "fc_to_conv",
                "serialize_conv",
                "stable_gelu",
                "fused_softmax",
                "attention_reshape_elim",
            ]
        );
        assert_eq!(reg.len(), 6);
        assert!(!reg.is_empty());
    }

    #[test]
    fn specs_build_against_a_context() {
        let rules = RuleSet::default();
        for spec in PassRegistry::standard().specs() {
            let pass = spec.build(&rules, &GPU_ADRENO740);
            // report labels are distinct from registry names but stable
            assert!(!pass.name().is_empty());
            assert!(!spec.summary.is_empty());
        }
    }

    #[test]
    fn subset_preserves_pipeline_order_and_rejects_unknowns() {
        let reg = PassRegistry::standard();
        // names given out of order still run in pipeline order
        let sub = reg.subset(&["stable_gelu", "groupnorm"]).unwrap();
        assert_eq!(sub.names(), vec!["groupnorm", "stable_gelu"]);
        assert!(reg.subset(&["warp_speed"]).is_err());
        assert!(reg.subset(&[]).unwrap().is_empty(), "empty subset = baseline");
    }

    #[test]
    fn without_drops_passes() {
        let reg = PassRegistry::standard().without(&["serialize_conv"]);
        assert_eq!(reg.len(), 5);
        assert!(reg.get("serialize_conv").is_none());
        assert!(reg.get("groupnorm").is_some());
    }
}
