//! Broadcast-free group normalization rewrite (paper Sec. 3.1 / Fig. 7).
//!
//! The TFLite export of group norm is a subgraph: rank-5 Reshape, Mean,
//! BroadcastTo, SquaredDifference, Mean, Add(eps), Rsqrt, BroadcastTo,
//! Sub, Mul, Reshape.  BroadcastTo is not delegable and the rank-5
//! tensors violate the delegate's rank limit, so the whole island falls
//! back to the CPU.  This pass re-emits the Fig.-7-right form: a rank-4
//! `(N, H*W, G, C/G)` layout where Mean keeps its dims and the
//! normalization proceeds with implicit (delegable) broadcasting —
//! no BroadcastTo, nothing above rank 4.
//!
//! Pattern: the anchor is the Reshape lifting `(N,H,W,C)` to the
//! rank-5 `(N,H,W,G,C/G)` view.  The island itself is irregular (the
//! exporter emits it with shared subexpressions), so the rewrite
//! callback floods the rank-5 region from the anchor and rejects the
//! site unless it is exactly the naive group-norm form: only
//! mean/broadcast/normalize ops inside, at least one BroadcastTo, one
//! closing rank-4 Reshape, and no rank-5 tensor leaking out.

use std::collections::BTreeMap;

use crate::graph::pattern::{self, Pattern, PatternNode};
use crate::graph::{Graph, Op, OpType, TensorId};

use super::Pass;

#[derive(Default)]
pub struct GroupNormRewrite;

/// A validated naive group-norm island.
struct Site {
    /// op ids, in graph order, of the whole island (reshape5 .. reshape4)
    ops: Vec<usize>,
    x_in: TensorId,
    out4: TensorId, // the rank-4 reshape output the affine consumes
    n: usize,
    h: usize,
    w: usize,
    groups: usize,
    cg: usize,
    name: String,
}

/// Flood the island from the anchoring rank-5 Reshape; `None` when the
/// region is not the naive group-norm form.
fn island_at(g: &Graph, anchor: usize) -> Option<Site> {
    let consumers = g.consumers();
    let op = &g.ops[anchor];
    let out = g.tensor(op.outputs[0]);
    let x_in = op.inputs[0];
    let xs = &g.tensor(x_in).shape;
    let (n, h, w) = (xs[0], xs[1], xs[2]);
    let (groups, cg) = (out.shape[3], out.shape[4]);

    // walk the island: all downstream ops whose tensors stay rank-5,
    // ending at the Reshape back to rank 4.
    let mut island = vec![op.id];
    let mut frontier = vec![op.outputs[0]];
    let mut out4 = None;
    let mut visited_ops = std::collections::BTreeSet::new();
    visited_ops.insert(op.id);
    let mut ok = true;
    while let Some(t) = frontier.pop() {
        for &c in &consumers[t] {
            if visited_ops.contains(&c) {
                continue;
            }
            let cop = &g.ops[c];
            match cop.ty {
                OpType::Reshape
                    if g.tensor(cop.outputs[0]).rank() == 4
                        && g.tensor(cop.outputs[0]).shape
                            == vec![n, h, w, groups * cg] =>
                {
                    visited_ops.insert(c);
                    island.push(c);
                    if out4.replace(cop.outputs[0]).is_some() {
                        ok = false;
                    }
                }
                OpType::Mean
                | OpType::BroadcastTo
                | OpType::SquaredDifference
                | OpType::Sub
                | OpType::Mul
                | OpType::Add
                | OpType::Rsqrt => {
                    visited_ops.insert(c);
                    island.push(c);
                    for &o in &cop.outputs {
                        if g.tensor(o).rank() == 5 {
                            frontier.push(o);
                        }
                    }
                }
                _ => {
                    ok = false;
                }
            }
        }
    }
    // the island must contain at least one BroadcastTo (else it is
    // not the naive form) and must have found the closing reshape
    let has_bcast = island.iter().any(|&i| g.ops[i].ty == OpType::BroadcastTo);
    if !ok || !has_bcast || out4.is_none() {
        return None;
    }
    // no op outside the island may read a rank-5 intermediate
    let island_set: std::collections::BTreeSet<usize> =
        island.iter().copied().collect();
    for &i in &island {
        for &o in &g.ops[i].outputs {
            if g.tensor(o).rank() == 5 {
                for &c in &consumers[o] {
                    if !island_set.contains(&c) {
                        return None;
                    }
                }
            }
        }
    }
    let mut ops: Vec<usize> = island_set.into_iter().collect();
    ops.sort();
    let name = op.name.trim_end_matches("/reshape5").trim_end_matches("/r5");
    Some(Site {
        ops,
        x_in,
        out4: out4.unwrap(),
        n,
        h,
        w,
        groups,
        cg,
        name: name.to_string(),
    })
}

/// Replace one island with the broadcast-free rank-4 form.
fn rewrite_site(g: &mut Graph, site: &Site) {
    let dt = g.tensor(site.x_in).dtype;
    let (n, hw, gr, cg) = (site.n, site.h * site.w, site.groups, site.cg);
    let nm = &site.name;

    // new rank-4 tensors
    let x4 = g.add_tensor(&format!("{nm}/bf_r4g"), &[n, hw, gr, cg], dt, false);
    let mean = g.add_tensor(&format!("{nm}/bf_mean"), &[n, 1, gr, 1], dt, false);
    let sq = g.add_tensor(&format!("{nm}/bf_sq"), &[n, hw, gr, cg], dt, false);
    let var = g.add_tensor(&format!("{nm}/bf_var"), &[n, 1, gr, 1], dt, false);
    let veps = g.add_tensor(&format!("{nm}/bf_veps"), &[n, 1, gr, 1], dt, false);
    let rstd = g.add_tensor(&format!("{nm}/bf_rstd"), &[n, 1, gr, 1], dt, false);
    let cent = g.add_tensor(&format!("{nm}/bf_center"), &[n, hw, gr, cg], dt, false);
    let norm = g.add_tensor(&format!("{nm}/bf_norm"), &[n, hw, gr, cg], dt, false);

    let mk = |ty, name: String, inputs: Vec<TensorId>, outputs: Vec<TensorId>| Op {
        id: usize::MAX,
        ty,
        name,
        inputs,
        outputs,
        attrs: BTreeMap::new(),
    };
    let new_ops = vec![
        mk(OpType::Reshape, format!("{nm}/bf_reshape_in"), vec![site.x_in], vec![x4]),
        mk(OpType::Mean, format!("{nm}/bf_mean_op"), vec![x4], vec![mean]),
        mk(OpType::SquaredDifference, format!("{nm}/bf_sqdiff"), vec![x4, mean], vec![sq]),
        mk(OpType::Mean, format!("{nm}/bf_var_op"), vec![sq], vec![var]),
        mk(OpType::Add, format!("{nm}/bf_eps"), vec![var], vec![veps]),
        mk(OpType::Rsqrt, format!("{nm}/bf_rsqrt"), vec![veps], vec![rstd]),
        mk(OpType::Sub, format!("{nm}/bf_center_op"), vec![x4, mean], vec![cent]),
        mk(OpType::Mul, format!("{nm}/bf_norm_op"), vec![cent, rstd], vec![norm]),
        mk(OpType::Reshape, format!("{nm}/bf_reshape_out"), vec![norm], vec![site.out4]),
    ];

    // splice: replace the island's op range.  Ops of the island are
    // contiguous in practice (emitted together), but be safe: remove
    // them all, insert the new ops at the first position.
    let first_pos = g
        .ops
        .iter()
        .position(|o| site.ops.contains(&o.id))
        .expect("island present");
    g.ops.retain(|o| !site.ops.contains(&o.id));
    let at = first_pos.min(g.ops.len());
    g.ops.splice(at..at, new_ops);
}

impl Pass for GroupNormRewrite {
    fn name(&self) -> &'static str {
        "groupnorm-broadcast-free"
    }

    fn run(&self, g: &mut Graph) -> usize {
        // anchor: a Reshape lifting rank-4 (N,H,W,C) to rank-5
        // (N,H,W,G,C/G)
        let pat = Pattern::new(PatternNode::op(OpType::Reshape).pred(|ctx, op| {
            let out = ctx.graph.tensor(op.outputs[0]);
            if out.rank() != 5 {
                return false;
            }
            let xs = &ctx.graph.tensor(op.inputs[0]).shape;
            xs.len() == 4
                && out.shape[..3] == xs[..3]
                && out.shape[3] * out.shape[4] == xs[3]
        }));
        pattern::apply(g, self.name(), &pat, |g, m| match island_at(g, m.anchor) {
            Some(site) => {
                rewrite_site(g, &site);
                true
            }
            None => false,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delegate::RuleSet;
    use crate::graph::builder::GraphBuilder;

    fn naive_gn_graph() -> Graph {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 16, 16, 64]);
        let y = b.conv2d("pre", x, 64, 3, 1);
        let z = b.group_norm_naive("gn", y, 8);
        b.conv2d("post", z, 64, 3, 1);
        b.finish()
    }

    #[test]
    fn detects_and_rewrites() {
        let mut g = naive_gn_graph();
        let rules = RuleSet::default();
        assert!(!rules.failures(&g).is_empty(), "naive GN must fail");

        let n = GroupNormRewrite.run(&mut g);
        assert_eq!(n, 1);
        g.validate().unwrap();

        let hist = g.op_histogram();
        assert_eq!(hist.get(&OpType::BroadcastTo), None, "no BroadcastTo left");
        assert!(g.max_rank() <= 4, "no rank-5 tensors left");
        assert!(rules.failures(&g).is_empty(), "{:?}", rules.failures(&g));
    }

    #[test]
    fn affine_ops_preserved() {
        // gamma-mul and beta-add consume the rank-4 output: must survive
        let mut g = naive_gn_graph();
        let muls_before =
            g.ops.iter().filter(|o| o.name.ends_with("/gmul")).count();
        GroupNormRewrite.run(&mut g);
        let muls_after =
            g.ops.iter().filter(|o| o.name.ends_with("/gmul")).count();
        assert_eq!(muls_before, 1);
        assert_eq!(muls_after, 1);
    }

    #[test]
    fn idempotent() {
        let mut g = naive_gn_graph();
        GroupNormRewrite.run(&mut g);
        let ops_after_first = g.ops.len();
        assert_eq!(GroupNormRewrite.run(&mut g), 0);
        assert_eq!(g.ops.len(), ops_after_first);
    }

    #[test]
    fn multiple_sites() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 8, 8, 32]);
        let a = b.group_norm_naive("gn1", x, 4);
        let c = b.conv2d("mid", a, 32, 3, 1);
        b.group_norm_naive("gn2", c, 8);
        let mut g = b.finish();
        assert_eq!(GroupNormRewrite.run(&mut g), 2);
        g.validate().unwrap();
        assert_eq!(g.op_histogram().get(&OpType::BroadcastTo), None);
    }
}
