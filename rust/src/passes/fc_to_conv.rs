//! FullyConnected -> Reshape / 1x1 Conv2D / Reshape (paper Fig. 1a).
//!
//! The TFLite GPU delegate rejects large FULLY_CONNECTED layers (our
//! rule: > 2048 flattened rows) but accepts the numerically identical
//! 1x1 convolution at any size, because the conv takes the tiled matmul
//! path.  The paper converts *all* FC layers ("converting all
//! FullyConnected operators into equivalent Conv2D operators is
//! preferable"), noting equal latency — this pass does the same by
//! default, with an optional `only_failing` mode used by the ablation
//! bench.
//!
//! Pattern: a bare `FULLY_CONNECTED` anchor (plus the delegate-verdict
//! predicate in `only_failing` mode); the rewrite re-types the op in
//! place and splices the surrounding reshapes.

use std::collections::BTreeMap;

use crate::delegate::RuleSet;
use crate::graph::pattern::{self, Pattern, PatternNode};
use crate::graph::{Graph, OpType};

use super::Pass;

pub struct FcToConv {
    /// rewrite only the FCs the delegate would reject (ablation mode)
    pub only_failing: bool,
    pub rules: RuleSet,
}

impl Default for FcToConv {
    fn default() -> Self {
        FcToConv { only_failing: false, rules: RuleSet::default() }
    }
}

impl Pass for FcToConv {
    fn name(&self) -> &'static str {
        "fc-to-conv"
    }

    fn run(&self, g: &mut Graph) -> usize {
        let only_failing = self.only_failing;
        let rules = self.rules.clone();
        let pat = Pattern::new(PatternNode::op(OpType::FullyConnected).pred(
            move |ctx, op| !only_failing || !rules.check(ctx.graph, op).ok(),
        ));
        pattern::apply(g, self.name(), &pat, |g, m| {
            rewrite_site(g, m.anchor);
            true
        })
    }
}

/// Convert the FC at `op_id` into Reshape / 1x1 Conv2D / Reshape.
fn rewrite_site(g: &mut Graph, op_id: usize) {
    // driver invariant: op ids equal positions until we splice below
    let pos0 = op_id;
    let (x_id, w_id, b_id, out_id, name) = {
        let op = &g.ops[pos0];
        let mut acts = op.inputs.iter().filter(|&&t| !g.tensor(t).is_const);
        let x = *acts.next().expect("fc has input");
        let mut consts = op.inputs.iter().filter(|&&t| g.tensor(t).is_const);
        let w = consts.next().copied();
        let b = consts.next().copied();
        (x, w, b, op.outputs[0], op.name.clone())
    };
    let x_shape = g.tensor(x_id).shape.clone();
    let out_shape = g.tensor(out_id).shape.clone();
    let d_in = *x_shape.last().unwrap();
    let d_out = *out_shape.last().unwrap();
    let rows: usize = x_shape[..x_shape.len() - 1].iter().product();
    let act_dtype = g.tensor(x_id).dtype;

    // Reshape x -> (1, 1, rows, d_in)
    let x4 = g.add_tensor(
        &format!("{name}/as_nhwc"),
        &[1, 1, rows, d_in],
        act_dtype,
        false,
    );
    // weight (d_in, d_out) viewed as 1x1 HWIO kernel
    let w4 = match w_id {
        Some(w) => {
            let dt = g.tensor(w).dtype;
            g.add_tensor(&format!("{name}/w_1x1"), &[1, 1, d_in, d_out], dt, true)
        }
        None => g.add_tensor(
            &format!("{name}/w_1x1"),
            &[1, 1, d_in, d_out],
            crate::graph::DType::F32,
            true,
        ),
    };
    let y4 = g.add_tensor(
        &format!("{name}/conv_out"),
        &[1, 1, rows, d_out],
        act_dtype,
        false,
    );

    // rewrite in place: FC op becomes the Conv2d; add reshapes
    // around it by splicing new ops into the op list.
    let mut attrs = BTreeMap::new();
    attrs.insert("kernel".to_string(), 1.0);
    attrs.insert("stride".to_string(), 1.0);
    attrs.insert("from_fc".to_string(), 1.0);

    let reshape_in_name = format!("{name}/reshape_in");
    let reshape_out_name = format!("{name}/reshape_out");
    let conv_inputs = match b_id {
        Some(b) => vec![x4, w4, b],
        None => vec![x4, w4],
    };

    let op = &mut g.ops[pos0];
    op.ty = OpType::Conv2d;
    op.inputs = conv_inputs;
    op.outputs = vec![y4];
    op.attrs = attrs;

    // splice Reshape ops before/after while keeping topo order; the
    // driver renumbers op ids after the rewrite
    let reshape_in = crate::graph::Op {
        id: usize::MAX,
        ty: OpType::Reshape,
        name: reshape_in_name,
        inputs: vec![x_id],
        outputs: vec![x4],
        attrs: BTreeMap::new(),
    };
    let reshape_out = crate::graph::Op {
        id: usize::MAX,
        ty: OpType::Reshape,
        name: reshape_out_name,
        inputs: vec![y4],
        outputs: vec![out_id],
        attrs: BTreeMap::new(),
    };
    g.ops.insert(pos0, reshape_in);
    g.ops.insert(pos0 + 2, reshape_out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delegate::RuleSet;
    use crate::graph::builder::GraphBuilder;

    fn fc_graph(rows: usize, d_in: usize, d_out: usize) -> Graph {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, rows, d_in]);
        b.fully_connected("fc", x, d_out);
        b.finish()
    }

    #[test]
    fn rewrites_paper_shape() {
        let mut g = fc_graph(4096, 320, 1280);
        let rules = RuleSet::default();
        assert!(!rules.check(&g, &g.ops[0]).ok(), "precondition: FC fails");

        let n = FcToConv::default().run(&mut g);
        assert_eq!(n, 1);
        g.validate().unwrap();

        let hist = g.op_histogram();
        assert_eq!(hist.get(&OpType::FullyConnected), None);
        assert_eq!(hist[&OpType::Conv2d], 1);
        assert_eq!(hist[&OpType::Reshape], 2);
        // everything now delegates (1x1 conv takes the matmul path)
        assert!(rules.failures(&g).is_empty());
    }

    #[test]
    fn preserves_output_tensor() {
        let mut g = fc_graph(64, 16, 8);
        let out_shape = g.tensor(g.ops[0].outputs[0]).shape.clone();
        let out_id = g.ops[0].outputs[0];
        FcToConv::default().run(&mut g);
        g.validate().unwrap();
        // the original output tensor is still produced, same shape
        let produced: Vec<_> =
            g.ops.iter().flat_map(|o| o.outputs.iter().copied()).collect();
        assert!(produced.contains(&out_id));
        assert_eq!(g.tensor(out_id).shape, out_shape);
    }

    #[test]
    fn only_failing_mode_skips_small_fc() {
        let mut g = fc_graph(77, 1024, 4096);
        let n = FcToConv { only_failing: true, rules: RuleSet::default() }.run(&mut g);
        assert_eq!(n, 0);
        assert_eq!(g.op_histogram()[&OpType::FullyConnected], 1);
    }

    #[test]
    fn default_mode_rewrites_all() {
        let mut g = fc_graph(77, 1024, 4096);
        let n = FcToConv::default().run(&mut g);
        assert_eq!(n, 1);
    }
}
