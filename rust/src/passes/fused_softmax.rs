//! Softmax-island fusion (paper-adjacent: "Speed Is All You Need",
//! arXiv 2304.11267, Sec. 3 — fused softmax kernels).
//!
//! The TFLite export of attention softmax is a three-op island over
//! the logits: `Exp -> Sum(keepdims) -> Div`, with the full-size
//! exponentials tensor written to memory twice (once by Exp, read
//! again by both Sum and Div).  On memory-bound mobile hardware the
//! island pays three dispatches and ~5 logits-sized memory round
//! trips.  This pass collapses it into a single [`OpType::FusedSoftmax`]
//! op — one dispatch, one streaming pass — whose memory-bound cost
//! entry lives in `delegate::cost`.
//!
//! Pattern (a multi-consumer island — `Exp`'s output feeds both the
//! reduction and the division):
//!
//! ```text
//! Div( Exp(x), Sum(Exp(x)) )     consumers(exp) == {Sum, Div} exactly
//! ```
//!
//! The plain single-op `SOFTMAX` is deliberately left alone: it is
//! already one dispatch, and re-typing it would change nothing the
//! cost model can see.

use std::collections::BTreeMap;

use crate::graph::pattern::{self, Match, Pattern, PatternNode};
use crate::graph::{Graph, OpType};

use super::Pass;

#[derive(Default)]
pub struct FusedSoftmaxPass;

fn softmax_pattern() -> Pattern {
    let exp = PatternNode::op(OpType::Exp).named("exp");
    let sum = PatternNode::op(OpType::Sum).named("sum").single_use();
    let root = PatternNode::op(OpType::Div)
        .named("div")
        .operand(0, pattern::OperandPattern::Produced(exp))
        .operand(1, pattern::OperandPattern::Produced(sum));
    Pattern::new(root).guard(|ctx, m| {
        let g = ctx.graph;
        let exp = &g.ops[m.op("exp")];
        let sum = &g.ops[m.op("sum")];
        let div = &g.ops[m.op("div")];
        let exp_out = exp.outputs[0];
        // the reduction must consume the same exponentials the division
        // normalizes
        if sum.inputs.first().copied() != Some(exp_out) {
            return false;
        }
        // the exponentials must feed exactly the island (Sum + Div):
        // with any other reader, Exp has to survive and fusing buys
        // nothing
        let mut readers: Vec<usize> = ctx.consumers[exp_out].clone();
        readers.sort_unstable();
        let mut island = [sum.id, div.id];
        island.sort_unstable();
        if readers != island {
            return false;
        }
        // keepdims last-axis reduction shape: exp shape with last dim 1
        let es = &g.tensor(exp_out).shape;
        let ss = &g.tensor(sum.outputs[0]).shape;
        if es.is_empty()
            || ss.len() != es.len()
            || *ss.last().unwrap() != 1
            || ss[..ss.len() - 1] != es[..es.len() - 1]
        {
            return false;
        }
        // softmax preserves the logits' shape and dtype end to end
        let x = exp.inputs[0];
        g.tensor(div.outputs[0]).shape == g.tensor(x).shape
            && g.tensor(div.outputs[0]).dtype == g.tensor(x).dtype
    })
}

impl Pass for FusedSoftmaxPass {
    fn name(&self) -> &'static str {
        "fused-softmax"
    }

    fn run(&self, g: &mut Graph) -> usize {
        let pat = softmax_pattern();
        pattern::apply(g, self.name(), &pat, |g, m| {
            rewrite_site(g, m);
            true
        })
    }
}

/// Replace the exp/sum/div island with one FusedSoftmax op producing
/// the island's output tensor from the island's input.
fn rewrite_site(g: &mut Graph, m: &Match) {
    let exp_id = m.op("exp");
    let sum_id = m.op("sum");
    let div_id = m.op("div");
    // driver invariant: op ids equal positions until we retain below
    let exp_pos = exp_id;
    let (x, out, stem) = {
        let exp = &g.ops[exp_pos];
        let div = &g.ops[div_id];
        let stem = div.name.trim_end_matches("/div").to_string();
        (exp.inputs[0], div.outputs[0], stem)
    };
    let mut attrs = BTreeMap::new();
    // last-axis softmax, the only form the pattern admits
    attrs.insert("axis".to_string(), (g.tensor(x).rank() as f64) - 1.0);
    let fused = crate::graph::Op {
        id: usize::MAX,
        ty: OpType::FusedSoftmax,
        name: format!("{stem}/fused"),
        inputs: vec![x],
        outputs: vec![out],
        attrs,
    };
    g.ops.retain(|o| o.id != exp_id && o.id != sum_id && o.id != div_id);
    let at = exp_pos.min(g.ops.len());
    g.ops.insert(at, fused);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delegate::{op_latency, segment_cost, RuleSet, GPU_ADRENO740};
    use crate::graph::builder::GraphBuilder;

    fn island_graph() -> Graph {
        let mut b = GraphBuilder::new("t");
        let x = b.input("logits", &[4, 64, 64]);
        let a = b.softmax_decomposed("sm", x);
        b.unary(OpType::Tanh, "post", a);
        b.finish()
    }

    #[test]
    fn fuses_the_island() {
        let mut g = island_graph();
        let n = FusedSoftmaxPass.run(&mut g);
        assert_eq!(n, 1);
        g.validate().unwrap();
        let hist = g.op_histogram();
        assert_eq!(hist.get(&OpType::Exp), None);
        assert_eq!(hist.get(&OpType::Sum), None);
        assert_eq!(hist.get(&OpType::Div), None);
        assert_eq!(hist[&OpType::FusedSoftmax], 1);
        // the fused op reads the logits and produces the island output
        let f = g.ops.iter().find(|o| o.ty == OpType::FusedSoftmax).unwrap();
        assert_eq!(g.tensor(f.inputs[0]).name, "logits");
        assert_eq!(f.attrs["axis"], 2.0);
    }

    #[test]
    fn idempotent_and_consumer_preserving() {
        let mut g = island_graph();
        FusedSoftmaxPass.run(&mut g);
        let ops_after = g.ops.len();
        assert_eq!(FusedSoftmaxPass.run(&mut g), 0);
        assert_eq!(g.ops.len(), ops_after);
        // downstream tanh still reads the softmax output
        let f = g.ops.iter().find(|o| o.ty == OpType::FusedSoftmax).unwrap();
        let post = g.ops.iter().find(|o| o.name == "post").unwrap();
        assert_eq!(post.inputs[0], f.outputs[0]);
    }

    #[test]
    fn extra_exp_reader_blocks_fusion() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("logits", &[4, 16, 16]);
        let a = b.softmax_decomposed("sm", x);
        let _ = a;
        // a second reader of the exponentials outside the island
        let exp_out = b.g.ops.iter().find(|o| o.ty == OpType::Exp).unwrap().outputs[0];
        b.unary(OpType::Tanh, "spy", exp_out);
        let mut g = b.finish();
        assert_eq!(FusedSoftmaxPass.run(&mut g), 0, "exp must survive");
    }

    #[test]
    fn plain_softmax_op_is_left_alone() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4, 16, 16]);
        b.unary(OpType::Softmax, "sm", x);
        let mut g = b.finish();
        assert_eq!(FusedSoftmaxPass.run(&mut g), 0);
        assert_eq!(g.op_histogram()[&OpType::Softmax], 1);
    }

    #[test]
    fn fused_op_is_memory_bound_and_cheaper_than_the_island() {
        let rules = RuleSet::default();
        let g_island = island_graph();
        let mut g_fused = island_graph();
        FusedSoftmaxPass.run(&mut g_fused);
        // full-graph GPU cost with elementwise fusion, like the
        // delegate would run it
        let all_island: Vec<usize> = (0..g_island.ops.len()).collect();
        let all_fused: Vec<usize> = (0..g_fused.ops.len()).collect();
        let t_island = segment_cost(&g_island, &all_island, &GPU_ADRENO740, true);
        let t_fused = segment_cost(&g_fused, &all_fused, &GPU_ADRENO740, true);
        assert!(
            t_fused < t_island,
            "fused {t_fused} !< island {t_island}"
        );
        // and the fused op's roofline is the memory side: latency tracks
        // bytes/bandwidth, not the 5-flops-per-element numerator
        let f = g_fused.ops.iter().find(|o| o.ty == OpType::FusedSoftmax).unwrap();
        let bytes = (g_fused.tensor(f.inputs[0]).bytes()
            + g_fused.tensor(f.outputs[0]).bytes()) as f64;
        let t = op_latency(&g_fused, f, &GPU_ADRENO740);
        let mem = GPU_ADRENO740.dispatch + bytes / GPU_ADRENO740.bandwidth;
        assert!((t - mem).abs() < 1e-9, "memory-bound: {t} vs {mem}");
        // coverage is untouched: every op involved delegates
        assert!(rules.failures(&g_fused).is_empty());
    }
}
