//! Numerically stable GELU rewrite (paper Sec. 3.2 / Fig. 8).
//!
//! Detects the decomposed tanh-GELU idiom (sq -> cube -> scale -> add ->
//! scale -> tanh -> 1+ -> 0.5x*) by its tanh anchor and inserts the
//! gamma_M clamp — a Minimum followed by a Maximum — in front of the
//! cubic chain, re-pointing the cube/add inputs at the clamped value.
//! The final `0.5 * x` product keeps reading the *unclamped* x, exactly
//! as in the paper's formula: GELU(x) ~= 0.5 x (1 + tanh(...gamma(x)...)).

use std::collections::BTreeMap;

use crate::graph::{Graph, OpType, TensorId};

use super::Pass;

pub struct StableGelu {
    /// the clip constant M (paper: 10)
    pub clip: f64,
}

impl Default for StableGelu {
    fn default() -> Self {
        StableGelu { clip: 10.0 }
    }
}

/// One detected GELU site: the ops that read the raw x inside the cubic
/// chain (sq, cube, add), which must be re-pointed at the clamp output.
struct Site {
    x: TensorId,
    /// (op_id, input_slot) pairs currently reading `x` in the chain
    reads: Vec<(usize, usize)>,
    anchor_pos: usize, // position in op list of the first chain op
    name: String,
}

fn find_sites(g: &Graph) -> Vec<Site> {
    let mut sites = Vec::new();
    let producers = g.producers();
    for op in &g.ops {
        if op.ty != OpType::Tanh {
            continue;
        }
        // walk backwards: tanh <- scale(Mul) <- add(Add{x, scale_cube})
        let scale = match producers[op.inputs[0]] {
            Some(p) if g.ops[p].ty == OpType::Mul => p,
            _ => continue,
        };
        let add = match producers[g.ops[scale].inputs[0]] {
            Some(p) if g.ops[p].ty == OpType::Add => p,
            _ => continue,
        };
        if g.ops[add].inputs.len() != 2 {
            continue;
        }
        // add's inputs: x and scale_cube(Mul <- cube(Mul{sq, x}) <- sq(Mul{x,x}))
        let (x, sc) = {
            let a = g.ops[add].inputs[0];
            let b = g.ops[add].inputs[1];
            // scale_cube is produced by a Mul whose chain bottoms out at x
            match (producers[a], producers[b]) {
                (_, Some(p)) if g.ops[p].ty == OpType::Mul && is_cubic(g, p, a, &producers) => (a, p),
                (Some(p), _) if g.ops[p].ty == OpType::Mul && is_cubic(g, p, b, &producers) => (b, p),
                _ => continue,
            }
        };
        // already stable? x produced by a Maximum (the clamp) -> skip
        if let Some(p) = producers[x] {
            if g.ops[p].ty == OpType::Maximum {
                continue;
            }
        }
        // gather the read sites of x in the chain: sq (both slots), cube,
        // add
        let cube = producers[g.ops[sc].inputs[0]].unwrap();
        let sq = producers[g.ops[cube].inputs[0]].unwrap();
        let mut reads = Vec::new();
        for (oid, op2) in [(sq, &g.ops[sq]), (cube, &g.ops[cube]), (add, &g.ops[add])] {
            for (slot, &inp) in op2.inputs.iter().enumerate() {
                if inp == x {
                    reads.push((oid, slot));
                }
            }
        }
        if reads.is_empty() {
            continue;
        }
        let anchor_pos = g.ops.iter().position(|o| o.id == sq).unwrap();
        let name = op.name.trim_end_matches("/tanh").to_string();
        sites.push(Site { x, reads, anchor_pos, name });
    }
    sites
}

/// Is `mul_op` the scale-cube of a cubic chain rooted at `x`?
/// pattern: sc = Mul(cube); cube = Mul(sq, x); sq = Mul(x, x)
fn is_cubic(g: &Graph, sc: usize, x: TensorId, producers: &[Option<usize>]) -> bool {
    let sc_op = &g.ops[sc];
    if sc_op.inputs.len() != 1 {
        return false;
    }
    let cube = match producers[sc_op.inputs[0]] {
        Some(p) if g.ops[p].ty == OpType::Mul => p,
        _ => return false,
    };
    let cube_op = &g.ops[cube];
    if cube_op.inputs.len() != 2 || !cube_op.inputs.contains(&x) {
        return false;
    }
    let sq_t = cube_op.inputs.iter().find(|&&t| t != x).copied();
    let sq_t = match sq_t {
        Some(t) => t,
        None => cube_op.inputs[0], // x * x * x with shared ids
    };
    match producers[sq_t] {
        Some(p) => {
            let sq_op = &g.ops[p];
            sq_op.ty == OpType::Mul && sq_op.inputs.iter().all(|&t| t == x)
        }
        None => false,
    }
}

impl Pass for StableGelu {
    fn name(&self) -> &'static str {
        "stable-gelu"
    }

    fn run(&self, g: &mut Graph) -> usize {
        // collect first: sites reference op ids, and we renumber at the end
        let sites = find_sites(g);
        // process in reverse op order so positions stay valid while splicing
        let mut ordered: Vec<&Site> = sites.iter().collect();
        ordered.sort_by_key(|s| std::cmp::Reverse(s.anchor_pos));

        for site in &ordered {
            let dt = g.tensor(site.x).dtype;
            let shape = g.tensor(site.x).shape.clone();
            let min_t =
                g.add_tensor(&format!("{}/clip_min", site.name), &shape, dt, false);
            let max_t =
                g.add_tensor(&format!("{}/clip_max", site.name), &shape, dt, false);
            let mut min_attrs = BTreeMap::new();
            min_attrs.insert("value".to_string(), self.clip);
            let mut max_attrs = BTreeMap::new();
            max_attrs.insert("value".to_string(), -self.clip);

            let min_op = crate::graph::Op {
                id: usize::MAX,
                ty: OpType::Minimum,
                name: format!("{}/gamma_min", site.name),
                inputs: vec![site.x],
                outputs: vec![min_t],
                attrs: min_attrs,
            };
            let max_op = crate::graph::Op {
                id: usize::MAX,
                ty: OpType::Maximum,
                name: format!("{}/gamma_max", site.name),
                inputs: vec![min_t],
                outputs: vec![max_t],
                attrs: max_attrs,
            };
            // re-point the chain's x reads at the clamp output
            for &(op_id, slot) in &site.reads {
                let pos = g.ops.iter().position(|o| o.id == op_id).unwrap();
                g.ops[pos].inputs[slot] = max_t;
            }
            g.ops.splice(site.anchor_pos..site.anchor_pos, [min_op, max_op]);
        }
        for (i, op) in g.ops.iter_mut().enumerate() {
            op.id = i;
        }
        sites.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    fn gelu_graph(stable: bool) -> Graph {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 256, 512]);
        let h = b.fully_connected("ff1", x, 512);
        let a = b.gelu("gelu", h, stable);
        b.fully_connected("ff2", a, 128);
        b.finish()
    }

    #[test]
    fn inserts_clamp() {
        let mut g = gelu_graph(false);
        let n = StableGelu::default().run(&mut g);
        assert_eq!(n, 1);
        g.validate().unwrap();
        let hist = g.op_histogram();
        assert_eq!(hist[&OpType::Minimum], 1);
        assert_eq!(hist[&OpType::Maximum], 1);
    }

    #[test]
    fn final_product_reads_unclamped_x() {
        // the 0.5*x multiplier outside tanh must keep reading raw x
        let mut g = gelu_graph(false);
        let half_x_op = g.ops.iter().find(|o| o.name.ends_with("/half_x")).unwrap();
        let raw_in = half_x_op.inputs[0];
        StableGelu::default().run(&mut g);
        let half_x_op = g.ops.iter().find(|o| o.name.ends_with("/half_x")).unwrap();
        assert_eq!(half_x_op.inputs[0], raw_in);
    }

    #[test]
    fn idempotent_on_already_stable() {
        let mut g = gelu_graph(true);
        assert_eq!(StableGelu::default().run(&mut g), 0);
    }

    #[test]
    fn rewrites_every_site() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 64, 128]);
        let mut cur = x;
        for i in 0..3 {
            let h = b.fully_connected(&format!("ff{i}"), cur, 128);
            cur = b.gelu(&format!("g{i}"), h, false);
        }
        let mut g = b.finish();
        assert_eq!(StableGelu::default().run(&mut g), 3);
        g.validate().unwrap();
        assert_eq!(g.op_histogram()[&OpType::Minimum], 3);
    }
}
