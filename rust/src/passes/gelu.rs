//! Numerically stable GELU rewrite (paper Sec. 3.2 / Fig. 8).
//!
//! Detects the decomposed tanh-GELU idiom (sq -> cube -> scale -> add ->
//! scale -> tanh -> 1+ -> 0.5x*) and inserts the gamma_M clamp — a
//! Minimum followed by a Maximum — in front of the cubic chain,
//! re-pointing the cube/add inputs at the clamped value.  The final
//! `0.5 * x` product keeps reading the *unclamped* x, exactly as in the
//! paper's formula: GELU(x) ~= 0.5 x (1 + tanh(...gamma(x)...)).
//!
//! Pattern (anchored at the tanh, walking producers backwards, with
//! `x` unified across the whole cubic chain):
//!
//! ```text
//! Tanh( Mul( Add( x, Mul( Mul( Mul(x, x), x ) ) ) ) )
//!                 ^commutative  ^commutative ^sq: both slots unify x
//! ```
//!
//! A guard skips sites whose `x` is already produced by a `Maximum`
//! (the clamp), making the pass idempotent under the driver's
//! fixed-point iteration.

use std::collections::BTreeMap;

use crate::graph::pattern::{self, Match, OperandPattern, Pattern, PatternNode};
use crate::graph::{Graph, OpType};

use super::Pass;

pub struct StableGelu {
    /// the clip constant M (paper: 10)
    pub clip: f64,
}

impl Default for StableGelu {
    fn default() -> Self {
        StableGelu { clip: 10.0 }
    }
}

fn gelu_pattern() -> Pattern {
    // sq = Mul(x, x): both operand slots unify against the same tensor
    let sq = PatternNode::op(OpType::Mul)
        .named("sq")
        .operand(0, OperandPattern::Tensor("x"))
        .operand(1, OperandPattern::Tensor("x"));
    // cube = Mul(sq, x), either operand order
    let cube = PatternNode::op(OpType::Mul)
        .named("cube")
        .operand(0, OperandPattern::Produced(sq))
        .operand(1, OperandPattern::Tensor("x"))
        .commutative();
    // sc = scale_cube: unary Mul of the cube
    let sc = PatternNode::op(OpType::Mul)
        .named("sc")
        .pred(|_, op| op.inputs.len() == 1)
        .operand(0, OperandPattern::Produced(cube));
    // add = Add(x, sc), either operand order
    let add = PatternNode::op(OpType::Add)
        .named("add")
        .pred(|_, op| op.inputs.len() == 2)
        .operand(0, OperandPattern::Tensor("x"))
        .operand(1, OperandPattern::Produced(sc))
        .commutative();
    let scale = PatternNode::op(OpType::Mul)
        .named("scale")
        .operand(0, OperandPattern::Produced(add));
    let root = PatternNode::op(OpType::Tanh)
        .named("tanh")
        .operand(0, OperandPattern::Produced(scale));
    // already stable? x produced by a Maximum (the clamp) -> skip
    Pattern::new(root).guard(|ctx, m| match ctx.producer_op(m.tensor("x")) {
        Some(op) => op.ty != OpType::Maximum,
        None => true,
    })
}

impl Pass for StableGelu {
    fn name(&self) -> &'static str {
        "stable-gelu"
    }

    fn run(&self, g: &mut Graph) -> usize {
        let pat = gelu_pattern();
        let clip = self.clip;
        pattern::apply(g, self.name(), &pat, |g, m| {
            rewrite_site(g, m, clip);
            true
        })
    }
}

/// Insert the gamma_M clamp in front of the cubic chain of one site
/// and re-point the chain's x reads at the clamped value.
fn rewrite_site(g: &mut Graph, m: &Match, clip: f64) {
    let x = m.tensor("x");
    let chain = [m.op("sq"), m.op("cube"), m.op("add")];
    // driver invariant: op ids equal positions until we splice below
    let mut reads = Vec::new();
    for &oid in &chain {
        for (slot, &inp) in g.ops[oid].inputs.iter().enumerate() {
            if inp == x {
                reads.push((oid, slot));
            }
        }
    }
    let anchor_pos = m.op("sq");
    let tanh_name = g.ops[m.op("tanh")].name.clone();
    let name = tanh_name.trim_end_matches("/tanh").to_string();

    let dt = g.tensor(x).dtype;
    let shape = g.tensor(x).shape.clone();
    let min_t = g.add_tensor(&format!("{name}/clip_min"), &shape, dt, false);
    let max_t = g.add_tensor(&format!("{name}/clip_max"), &shape, dt, false);
    let mut min_attrs = BTreeMap::new();
    min_attrs.insert("value".to_string(), clip);
    let mut max_attrs = BTreeMap::new();
    max_attrs.insert("value".to_string(), -clip);

    let min_op = crate::graph::Op {
        id: usize::MAX,
        ty: OpType::Minimum,
        name: format!("{name}/gamma_min"),
        inputs: vec![x],
        outputs: vec![min_t],
        attrs: min_attrs,
    };
    let max_op = crate::graph::Op {
        id: usize::MAX,
        ty: OpType::Maximum,
        name: format!("{name}/gamma_max"),
        inputs: vec![min_t],
        outputs: vec![max_t],
        attrs: max_attrs,
    };
    // re-point the chain's x reads at the clamp output
    for &(op_id, slot) in &reads {
        g.ops[op_id].inputs[slot] = max_t;
    }
    g.ops.splice(anchor_pos..anchor_pos, [min_op, max_op]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    fn gelu_graph(stable: bool) -> Graph {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 256, 512]);
        let h = b.fully_connected("ff1", x, 512);
        let a = b.gelu("gelu", h, stable);
        b.fully_connected("ff2", a, 128);
        b.finish()
    }

    #[test]
    fn inserts_clamp() {
        let mut g = gelu_graph(false);
        let n = StableGelu::default().run(&mut g);
        assert_eq!(n, 1);
        g.validate().unwrap();
        let hist = g.op_histogram();
        assert_eq!(hist[&OpType::Minimum], 1);
        assert_eq!(hist[&OpType::Maximum], 1);
    }

    #[test]
    fn final_product_reads_unclamped_x() {
        // the 0.5*x multiplier outside tanh must keep reading raw x
        let mut g = gelu_graph(false);
        let half_x_op = g.ops.iter().find(|o| o.name.ends_with("/half_x")).unwrap();
        let raw_in = half_x_op.inputs[0];
        StableGelu::default().run(&mut g);
        let half_x_op = g.ops.iter().find(|o| o.name.ends_with("/half_x")).unwrap();
        assert_eq!(half_x_op.inputs[0], raw_in);
    }

    #[test]
    fn idempotent_on_already_stable() {
        let mut g = gelu_graph(true);
        assert_eq!(StableGelu::default().run(&mut g), 0);
    }

    #[test]
    fn rewrites_every_site() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 64, 128]);
        let mut cur = x;
        for i in 0..3 {
            let h = b.fully_connected(&format!("ff{i}"), cur, 128);
            cur = b.gelu(&format!("g{i}"), h, false);
        }
        let mut g = b.finish();
        assert_eq!(StableGelu::default().run(&mut g), 3);
        g.validate().unwrap();
        assert_eq!(g.op_histogram()[&OpType::Minimum], 3);
    }

    #[test]
    fn clamp_value_attr_is_recorded() {
        let mut g = gelu_graph(false);
        StableGelu { clip: 6.0 }.run(&mut g);
        let min_op = g.ops.iter().find(|o| o.ty == OpType::Minimum).unwrap();
        assert_eq!(min_op.attrs["value"], 6.0);
        let max_op = g.ops.iter().find(|o| o.ty == OpType::Maximum).unwrap();
        assert_eq!(max_op.attrs["value"], -6.0);
    }
}
