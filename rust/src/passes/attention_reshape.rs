//! Cancelling Reshape/Transpose pair elimination around the attention
//! BatchMatmuls (paper-adjacent: MobileDiffusion, arXiv 2311.16567,
//! restructures attention layout for mobile).
//!
//! The exporter's layout legalization leaves identity round trips
//! behind — a Transpose immediately undone by its inverse (adj_y
//! folded into the K path, then unfolded), or a Reshape flattening a
//! head tensor that the very next Reshape restores.  Each pair costs
//! two dispatches and, for transposes, two full data-movement passes
//! over an attention-sized tensor, for a provable no-op.
//!
//! Pattern: two adjacent ops of the *same* kind (`RESHAPE`/`RESHAPE`
//! or `TRANSPOSE`/`TRANSPOSE`) where the inner result is single-use
//! and the pair provably composes to the identity:
//!
//! * Reshape pair — the outer output's shape equals the inner input's
//!   shape (row-major views compose to the identity by construction);
//! * Transpose pair — the recorded permutations compose to the
//!   identity (`p_inner[p_outer[i]] == i`); a transpose with no
//!   recorded permutation is never touched.
//!
//! A mixed Reshape-then-Transpose pair with coincidentally matching
//! shapes is NOT an identity and is deliberately rejected by the
//! same-kind guard.  The rewrite re-points every consumer of the pair
//! output at the pair input and deletes both ops; a pair whose output
//! nothing consumes (a graph output) is left alone.

use crate::graph::pattern::{self, Match, OperandPattern, Pattern, PatternNode};
use crate::graph::{Graph, Op, OpType};

use super::Pass;

#[derive(Default)]
pub struct AttentionReshapeElim;

/// The permutation recorded on a Transpose (`perm0..permN` attrs),
/// `None` when absent or malformed.
fn perm_of(op: &Op, rank: usize) -> Option<Vec<usize>> {
    let mut perm = Vec::with_capacity(rank);
    for i in 0..rank {
        let v = op.attr_i(&format!("perm{i}"))?;
        if v < 0 || v as usize >= rank {
            return None;
        }
        perm.push(v as usize);
    }
    Some(perm)
}

fn elim_pattern() -> Pattern {
    let inner = PatternNode::one_of(&[OpType::Reshape, OpType::Transpose])
        .named("inner")
        .single_use();
    let root = PatternNode::one_of(&[OpType::Reshape, OpType::Transpose])
        .named("outer")
        .operand(0, OperandPattern::Produced(inner));
    Pattern::new(root).guard(|ctx, m| {
        let g = ctx.graph;
        let outer = &g.ops[m.op("outer")];
        let inner = &g.ops[m.op("inner")];
        if outer.ty != inner.ty {
            return false;
        }
        let out_t = outer.outputs[0];
        // a pair nothing reads is a graph output; leave it in place
        if ctx.consumer_count(out_t) == 0 {
            return false;
        }
        let src = inner.inputs[0];
        if g.tensor(out_t).shape != g.tensor(src).shape
            || g.tensor(out_t).dtype != g.tensor(src).dtype
        {
            return false;
        }
        match outer.ty {
            OpType::Transpose => {
                let rank = g.tensor(src).rank();
                match (perm_of(inner, rank), perm_of(outer, rank)) {
                    (Some(pi), Some(po)) => {
                        (0..rank).all(|i| pi[po[i]] == i)
                    }
                    _ => false,
                }
            }
            // Reshape round trip: same shape in row-major order is the
            // identity by construction
            _ => true,
        }
    })
}

impl Pass for AttentionReshapeElim {
    fn name(&self) -> &'static str {
        "attention-reshape-elim"
    }

    fn run(&self, g: &mut Graph) -> usize {
        let pat = elim_pattern();
        pattern::apply(g, self.name(), &pat, |g, m| {
            rewrite_site(g, m);
            true
        })
    }
}

/// Re-point every reader of the pair output at the pair input and
/// delete both ops.
fn rewrite_site(g: &mut Graph, m: &Match) {
    let outer_id = m.op("outer");
    let inner_id = m.op("inner");
    let (src, out_t) = {
        let outer = g.ops.iter().find(|o| o.id == outer_id).unwrap();
        let inner = g.ops.iter().find(|o| o.id == inner_id).unwrap();
        (inner.inputs[0], outer.outputs[0])
    };
    for op in g.ops.iter_mut() {
        for inp in op.inputs.iter_mut() {
            if *inp == out_t {
                *inp = src;
            }
        }
    }
    g.ops.retain(|o| o.id != outer_id && o.id != inner_id);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    #[test]
    fn cancels_transpose_and_reshape_pairs_in_attention() {
        use crate::graph::OpType;
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 64, 32]);
        b.attention("attn", x, 4);
        let mut g = b.finish();
        let before = g.ops.len();
        let hist_before = g.op_histogram();
        let n = AttentionReshapeElim.run(&mut g);
        assert_eq!(n, 2, "one transpose pair (K path) + one reshape pair (V path)");
        g.validate().unwrap();
        assert_eq!(g.ops.len(), before - 4);
        let hist = g.op_histogram();
        assert_eq!(hist[&OpType::Transpose], hist_before[&OpType::Transpose] - 2);
        assert_eq!(hist[&OpType::Reshape], hist_before[&OpType::Reshape] - 2);
        // the V-path flatten/unflatten round trip is gone entirely; on
        // the K path's triple of identical [0,2,1] transposes the scan
        // cancels the *first* adjacent pair (k_swap, k_adj), leaving
        // k_unadj as the one real [H,N,D] -> [H,D,N] transpose QK^T
        // needs
        assert!(!g.ops.iter().any(|o| o.name.ends_with("/v_flat")
            || o.name.ends_with("/v_unflat")));
        assert!(!g.ops.iter().any(|o| o.name.ends_with("/k_swap")
            || o.name.ends_with("/k_adj")));
        assert!(g.ops.iter().any(|o| o.name.ends_with("/k_unadj")));
    }

    #[test]
    fn idempotent() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 64, 32]);
        b.attention("attn", x, 4);
        let mut g = b.finish();
        AttentionReshapeElim.run(&mut g);
        assert_eq!(AttentionReshapeElim.run(&mut g), 0);
    }

    #[test]
    fn non_inverse_transposes_survive() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[2, 3, 4]);
        let t1 = b.transpose("t1", x, &[1, 0, 2]);
        let t2 = b.transpose("t2", t1, &[0, 2, 1]); // [3,2,4] -> [3,4,2]
        b.unary(OpType::Tanh, "post", t2);
        let mut g = b.finish();
        assert_eq!(AttentionReshapeElim.run(&mut g), 0);
        assert_eq!(g.op_histogram()[&OpType::Transpose], 2);
    }

    #[test]
    fn mixed_kind_pairs_survive_even_with_matching_shapes() {
        // Transpose then Reshape back to the original shape is NOT an
        // identity (element order differs) — must not be cancelled
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[2, 3, 4]);
        let t = b.transpose("t", x, &[1, 0, 2]); // [3,2,4]
        let r = b.reshape("r", t, &[2, 3, 4]); // same shape as x again
        b.unary(OpType::Tanh, "post", r);
        let mut g = b.finish();
        assert_eq!(AttentionReshapeElim.run(&mut g), 0);
    }

    #[test]
    fn shared_inner_tensor_blocks_elimination() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[2, 3, 4]);
        let t1 = b.transpose("t1", x, &[1, 0, 2]);
        let t2 = b.transpose("t2", t1, &[1, 0, 2]); // inverse pair
        b.unary(OpType::Tanh, "post", t2);
        b.unary(OpType::Logistic, "spy", t1); // second reader of t1
        let mut g = b.finish();
        assert_eq!(AttentionReshapeElim.run(&mut g), 0);
    }

    #[test]
    fn reshape_round_trip_is_cancelled_and_repointed() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[2, 3, 4]);
        let flat = b.reshape("flat", x, &[6, 4]);
        let back = b.reshape("back", flat, &[2, 3, 4]);
        let out = b.unary(OpType::Tanh, "post", back);
        let _ = out;
        let mut g = b.finish();
        assert_eq!(AttentionReshapeElim.run(&mut g), 1);
        g.validate().unwrap();
        let post = g.ops.iter().find(|o| o.name == "post").unwrap();
        assert_eq!(post.inputs[0], 0, "tanh reads the original x");
        assert_eq!(g.op_histogram().get(&OpType::Reshape), None);
    }

    #[test]
    fn graph_output_pairs_are_left_alone() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[2, 3, 4]);
        let t1 = b.transpose("t1", x, &[1, 0, 2]);
        b.transpose("t2", t1, &[1, 0, 2]); // pair output IS the graph output
        let mut g = b.finish();
        assert_eq!(AttentionReshapeElim.run(&mut g), 0);
    }
}
