//! Graph transformation passes — the paper's Sec. 3.1 rewrites.
//!
//! Each pass rewrites the TFLite-level graph to remove a class of
//! delegation failures:
//!
//!  * [`fc_to_conv`]      — FullyConnected -> Reshape/1x1-Conv2D/Reshape
//!                          (paper Fig. 1a);
//!  * [`serialize_conv`]  — over-sized 3x3 convs split into the minimal
//!                          number of input-channel slices (Fig. 1b);
//!  * [`groupnorm`]       — broadcast-free group normalization, all
//!                          tensors rank <= 4 (Fig. 7);
//!  * [`gelu`]            — numerically stable GELU with the gamma_M
//!                          clamp (Sec. 3.2, Fig. 8).
//!
//! [`manager`] runs them in order and verifies the invariants the paper
//! relies on: shapes preserved at graph outputs, no BroadcastTo, no
//! rank-5 tensors, and full delegate coverage afterwards.

pub mod fc_to_conv;
pub mod gelu;
pub mod groupnorm;
pub mod manager;
pub mod serialize_conv;

pub use manager::{run_all, run_all_for, run_with_config, PassConfig, PassReport};

use crate::graph::Graph;

/// A graph-to-graph rewrite.
pub trait Pass {
    fn name(&self) -> &'static str;
    /// Apply in place; returns the number of sites rewritten.
    fn run(&self, g: &mut Graph) -> usize;
}
