//! Graph transformation passes — the paper's Sec. 3.1/3.2 rewrites
//! plus the attention fusions the pattern engine unlocked, all built
//! on [`crate::graph::pattern`]'s declarative match/rewrite core.
//!
//! Each pass removes a class of delegation failures or fuses away
//! dispatch/memory overhead:
//!
//!  * [`fc_to_conv`]         — FullyConnected -> Reshape/1x1-Conv2D/
//!                             Reshape (paper Fig. 1a);
//!  * [`serialize_conv`]     — over-sized 3x3 convs split into the
//!                             minimal number of channel slices
//!                             (Fig. 1b);
//!  * [`groupnorm`]          — broadcast-free group normalization, all
//!                             tensors rank <= 4 (Fig. 7);
//!  * [`gelu`]               — numerically stable GELU with the
//!                             gamma_M clamp (Sec. 3.2, Fig. 8);
//!  * [`fused_softmax`]      — the export-form `Exp -> Sum -> Div`
//!                             softmax island collapsed into one
//!                             memory-bound `FUSED_SOFTMAX` dispatch
//!                             ("Speed Is All You Need", arXiv
//!                             2304.11267): saves two dispatches and
//!                             the full-size exponentials round trip
//!                             per attention block;
//!  * [`attention_reshape`]  — cancelling Reshape/Transpose pairs the
//!                             exporter leaves around the attention
//!                             BatchMatmuls provably composed to the
//!                             identity and deleted (MobileDiffusion,
//!                             arXiv 2311.16567).
//!
//! [`registry`] is the single pipeline definition ([`PassRegistry`]):
//! run order, CLI names, and the planner's cost-gated trials all
//! derive from it.  [`manager`] runs a registry and verifies the
//! invariants the paper relies on: shapes preserved at graph outputs,
//! no BroadcastTo, no rank-5 tensors, and full delegate coverage
//! afterwards — the per-rewrite shape/dtype contract itself is
//! enforced by the pattern engine's driver.

pub mod attention_reshape;
pub mod fc_to_conv;
pub mod fused_softmax;
pub mod gelu;
pub mod groupnorm;
pub mod manager;
pub mod registry;
pub mod serialize_conv;

pub use manager::{run_all, run_all_for, run_registry, PassReport};
pub use registry::{PassRegistry, PassSpec};

use crate::graph::Graph;

/// A graph-to-graph rewrite.
pub trait Pass {
    fn name(&self) -> &'static str;
    /// Apply in place; returns the number of sites rewritten.
    fn run(&self, g: &mut Graph) -> usize;
}
