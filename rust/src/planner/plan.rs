//! Cost-gated execution planning: run the Sec. 3.1/3.2 pass pipeline
//! under the roofline cost model and keep only what the model says
//! pays off on *this* device class.
//!
//! The offline CLI applies every pass unconditionally; the planner is
//! stricter because its output drives admission control.  Each pass is
//! trialled in pipeline order on a scratch copy and accepted only if
//! it neither decreases delegation coverage nor increases modeled
//! latency for the device class being planned — on the GPU-delegate
//! class the whole pipeline typically lands (islands removed, the
//! failing conv serialized), while a complete-coverage comparator
//! class rejects a serialization that would only lose the Winograd
//! reduction.  By construction a plan is never worse than the
//! unplanned graph, which is the invariant the property tests pin.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::delegate::{
    class_breakdown, graph_cost, graph_cost_on, single_device_cost,
    single_device_cost_on, w8a8_gain, OpClass, RooflineModel, RuleSet,
};
use crate::error::Result;
use crate::graph::Graph;
use crate::passes::PassRegistry;

use super::calibrate::CalibratedProfile;
use super::model;
use super::registry::DeviceSpec;

/// Denoise dispatches run the CFG pair (uncond + cond) per step.
const CFG_ROWS: f64 = 2.0;

/// Modeled end-to-end latency of one forward pass of `g` on a device
/// class: delegate-partitioned for paired classes, single-device for
/// complete-coverage classes.
pub fn modeled_cost_s(g: &Graph, rules: &RuleSet, spec: &DeviceSpec) -> f64 {
    modeled_cost_cal(g, rules, spec, None)
}

/// [`modeled_cost_s`] with the primary device optionally priced by a
/// calibrated overlay instead of the shipped constants.  The CPU
/// fallback of paired classes keeps its shipped profile — calibration
/// windows are keyed by the class's primary device.
pub fn modeled_cost_cal(
    g: &Graph,
    rules: &RuleSet,
    spec: &DeviceSpec,
    cal: Option<&CalibratedProfile>,
) -> f64 {
    let model: &dyn RooflineModel = match cal {
        Some(c) => c,
        None => &spec.delegate,
    };
    match &spec.fallback {
        Some(cpu) => graph_cost_on(g, rules, model, cpu).total(),
        None => single_device_cost_on(g, model),
    }
}

/// Human form of a pass schedule: `"(none)"` or the comma-joined pass
/// names.  One definition for the metrics report, the CLI, and the
/// examples.
pub fn schedule_display(passes_used: &[&str]) -> String {
    if passes_used.is_empty() {
        "(none)".to_string()
    } else {
        passes_used.join(", ")
    }
}

/// The planner's verdict on one graph for one device class.
#[derive(Debug, Clone)]
pub struct PlannedGraph {
    pub graph: Graph,
    /// modeled latency of one forward pass, seconds
    pub cost_s: f64,
    /// delegate-rule coverage of the planned graph
    pub coverage: f64,
    /// rewrite sites applied across the accepted passes
    pub rewrites: usize,
    /// names of the passes the cost model accepted, pipeline order
    pub passes_used: Vec<&'static str>,
}

/// Plan one graph for one device class: trial each registered pass in
/// pipeline order — the order and the pass set both come from the one
/// [`PassRegistry::standard`] definition, so the planner can never
/// drift from `passes::run_all` — and accept a pass only if coverage
/// does not decrease and modeled latency does not increase.  Never
/// returns a graph worse than the input under either metric.
pub fn plan_graph(g: &Graph, rules: &RuleSet, spec: &DeviceSpec) -> PlannedGraph {
    plan_graph_with(g, rules, spec, &PassRegistry::standard())
}

/// [`plan_graph`] over an explicit registry (ablations, benches).
pub fn plan_graph_with(
    g: &Graph,
    rules: &RuleSet,
    spec: &DeviceSpec,
    registry: &PassRegistry,
) -> PlannedGraph {
    plan_graph_cal(g, rules, spec, registry, None)
}

/// [`plan_graph_with`] pricing pass trials against a calibrated
/// overlay.  The accept gate is unchanged (coverage must not decrease,
/// modeled latency must not increase), so the never-worse invariant
/// holds under *any* roofline model — a property test pins this.
pub fn plan_graph_cal(
    g: &Graph,
    rules: &RuleSet,
    spec: &DeviceSpec,
    registry: &PassRegistry,
    cal: Option<&CalibratedProfile>,
) -> PlannedGraph {
    let mut current = g.clone();
    let mut cost_s = modeled_cost_cal(&current, rules, spec, cal);
    let mut coverage = rules.coverage(&current);
    let mut rewrites = 0usize;
    let mut passes_used = Vec::new();

    for pass_spec in registry.specs() {
        let mut candidate = current.clone();
        let n = pass_spec.build(rules, &spec.delegate).run(&mut candidate);
        if n == 0 {
            continue;
        }
        let cand_cost = modeled_cost_cal(&candidate, rules, spec, cal);
        let cand_cov = rules.coverage(&candidate);
        if cand_cov >= coverage && cand_cost <= cost_s {
            current = candidate;
            cost_s = cand_cost;
            coverage = cand_cov;
            rewrites += n;
            passes_used.push(pass_spec.name);
        }
    }

    PlannedGraph { graph: current, cost_s, coverage, rewrites, passes_used }
}

/// The modeled work signature of one component dispatch at batch 1:
/// what the executor reports alongside each measured wall so the
/// calibrator can fit (work → latency).  `class` is the op class that
/// dominates the component's modeled latency.
#[derive(Debug, Clone, Copy)]
pub struct StageSig {
    pub class: OpClass,
    /// modeled FLOPs of one forward pass at batch 1
    pub flops: f64,
    /// modeled bytes moved by one forward pass at batch 1
    pub bytes: f64,
}

fn stage_sig(g: &Graph, dev: &crate::delegate::DeviceProfile) -> StageSig {
    let rows = class_breakdown(g, dev, dev);
    let mut class = OpClass::Elementwise;
    let mut dominant = -1.0;
    let (mut flops, mut bytes) = (0.0, 0.0);
    for (i, row) in rows.iter().enumerate() {
        flops += row.flops;
        bytes += row.bytes;
        if row.modeled_s > dominant {
            dominant = row.modeled_s;
            class = OpClass::ALL[i];
        }
    }
    StageSig { class, flops, bytes }
}

/// What the scheduler needs to know about running one `(device class,
/// variant)` combination: predicted per-step latency, fixed per-request
/// overhead, delegated coverage, and modeled peak memory.
#[derive(Debug, Clone)]
pub struct ExecutionPlan {
    /// registry name of the device class
    pub device: String,
    pub variant: String,
    /// delegated coverage of the planned UNet (1.0 for single-device
    /// classes — complete coverage by construction)
    pub coverage: f64,
    /// one CFG-batched denoise dispatch (uncond + cond UNet rows)
    pub step_latency_s: f64,
    /// per-request fixed cost: text encode + decode forward passes
    pub overhead_s: f64,
    /// modeled resident peak: UNet weights + max(encoder, decoder)
    /// weights + the largest live activation (the paper's pipelined
    /// shape, Sec. 3.3)
    pub peak_memory: usize,
    /// rewrite sites the cost model accepted across all components
    pub rewrites: usize,
    /// accepted passes on the UNet, pipeline order
    pub unet_passes: Vec<&'static str>,
    /// W8A8 activation quantization pays on this pair: the pricing
    /// model says the bandwidth saved across the planned UNet beats
    /// the boundary quant/dequant cost ([`w8a8_gain`] > 0)
    pub w8a8: bool,
    /// true when this plan was priced against a calibrated overlay
    /// rather than the shipped constants
    pub calibrated: bool,
    /// work signature of one UNet denoise row (batch 1)
    pub unet_sig: StageSig,
    /// work signature of one text-encoder forward pass
    pub text_sig: StageSig,
    /// work signature of one decoder forward pass
    pub decode_sig: StageSig,
}

fn weight_bytes(g: &Graph) -> usize {
    g.tensors.iter().filter(|t| t.is_const).map(|t| t.bytes()).sum()
}

fn peak_activation_bytes(g: &Graph) -> usize {
    g.tensors
        .iter()
        .filter(|t| !t.is_const)
        .map(|t| t.bytes())
        .max()
        .unwrap_or(0)
}

/// Largest live activation charged at int8 width — what the ledger
/// holds when the plan enables W8A8 activation quantization.
fn peak_activation_bytes_int8(g: &Graph) -> usize {
    g.tensors
        .iter()
        .filter(|t| !t.is_const)
        .map(|t| t.elems() * crate::quant::activations::INT8_BYTES_PER_ELEM)
        .max()
        .unwrap_or(0)
}

impl ExecutionPlan {
    /// Plan every component of `variant` for `spec` under the shipped
    /// cost constants.
    pub fn build(spec: &DeviceSpec, variant: &str, rules: &RuleSet) -> Result<ExecutionPlan> {
        ExecutionPlan::build_cal(spec, variant, rules, None)
    }

    /// [`ExecutionPlan::build`] pricing against a calibrated overlay:
    /// pass gating, the W8A8 decision, and the predicted latencies all
    /// use the fitted per-op-class parameters where available.
    pub fn build_cal(
        spec: &DeviceSpec,
        variant: &str,
        rules: &RuleSet,
        cal: Option<&CalibratedProfile>,
    ) -> Result<ExecutionPlan> {
        let registry = PassRegistry::standard();
        let (unet, text, dec) = model::component_graphs(variant)?;
        let unet_p = plan_graph_cal(&unet, rules, spec, &registry, cal);
        let text_p = plan_graph_cal(&text, rules, spec, &registry, cal);
        let dec_p = plan_graph_cal(&dec, rules, spec, &registry, cal);
        let coverage = if spec.is_single_device() { 1.0 } else { unet_p.coverage };
        let model: &dyn RooflineModel = match cal {
            Some(c) => c,
            None => &spec.delegate,
        };
        let w8a8 = w8a8_gain(&unet_p.graph, model) > 0.0;
        // W8A8 buys ledger headroom too: int8 activation buffers are
        // charged at 1 byte/elem instead of their fp32 width
        let act_peak = if w8a8 {
            peak_activation_bytes_int8(&unet_p.graph)
        } else {
            peak_activation_bytes(&unet_p.graph)
        };
        let peak_memory = weight_bytes(&unet_p.graph)
            + weight_bytes(&text_p.graph).max(weight_bytes(&dec_p.graph))
            + act_peak;
        Ok(ExecutionPlan {
            device: spec.name.to_string(),
            variant: variant.to_string(),
            coverage,
            step_latency_s: CFG_ROWS * unet_p.cost_s,
            overhead_s: text_p.cost_s + dec_p.cost_s,
            peak_memory,
            rewrites: unet_p.rewrites + text_p.rewrites + dec_p.rewrites,
            unet_passes: unet_p.passes_used,
            w8a8,
            calibrated: cal.map(|c| c.is_calibrated()).unwrap_or(false),
            unet_sig: stage_sig(&unet_p.graph, &spec.delegate),
            text_sig: stage_sig(&text_p.graph, &spec.delegate),
            decode_sig: stage_sig(&dec_p.graph, &spec.delegate),
        })
    }

    /// Plan-predicted service time of one request at `num_steps`.
    pub fn predict_service_s(&self, num_steps: usize) -> f64 {
        self.predict_service_with(num_steps, None)
    }

    /// Service-time prediction with the fixed overhead term optionally
    /// replaced by a *measured* per-request overhead (the fleet's
    /// observed load + encode + decode time on this device class).
    /// The modeled constant is only the bootstrap; once workers have
    /// served enough requests the router feeds their numbers back in.
    pub fn predict_service_with(&self, num_steps: usize, observed_overhead_s: Option<f64>) -> f64 {
        observed_overhead_s.unwrap_or(self.overhead_s)
            + num_steps as f64 * self.step_latency_s
    }
}

/// Shared, lazily-built cache of execution plans, keyed by
/// `(device class, variant)`.  One registry serves the whole pool:
/// admission routing, worker startup, and the CLI all read the same
/// plans, and each combination pays the pass pipeline exactly once.
#[derive(Debug)]
pub struct PlanRegistry {
    rules: RuleSet,
    plans: Mutex<BTreeMap<(String, String), Arc<ExecutionPlan>>>,
    replans: AtomicU64,
}

impl PlanRegistry {
    pub fn new() -> PlanRegistry {
        PlanRegistry::with_rules(RuleSet::default())
    }

    pub fn with_rules(rules: RuleSet) -> PlanRegistry {
        PlanRegistry { rules, plans: Mutex::new(BTreeMap::new()), replans: AtomicU64::new(0) }
    }

    /// The cached plan for `(spec, variant)`, building it on first use.
    pub fn plan(&self, spec: &DeviceSpec, variant: &str) -> Result<Arc<ExecutionPlan>> {
        let key = (spec.name.to_string(), variant.to_string());
        if let Some(p) = self.plans.lock().unwrap().get(&key) {
            return Ok(Arc::clone(p));
        }
        // build outside the lock: the pass pipeline is the slow part
        let built = Arc::new(ExecutionPlan::build(spec, variant, &self.rules)?);
        let mut plans = self.plans.lock().unwrap();
        Ok(Arc::clone(plans.entry(key).or_insert(built)))
    }

    /// Rebuild `(spec, variant)` against a calibrated overlay and swap
    /// the result into the cache, invalidating whatever was there.
    /// Callers (the fleet router) decide *when* — typically when the
    /// overlay's divergence from the model the cached plan was built
    /// under crosses [`super::calibrate::REPLAN_DIVERGENCE`].
    pub fn replan(
        &self,
        spec: &DeviceSpec,
        variant: &str,
        cal: &CalibratedProfile,
    ) -> Result<Arc<ExecutionPlan>> {
        let key = (spec.name.to_string(), variant.to_string());
        // build outside the lock, same as plan()
        let built = Arc::new(ExecutionPlan::build_cal(spec, variant, &self.rules, Some(cal))?);
        self.plans.lock().unwrap().insert(key, Arc::clone(&built));
        self.replans.fetch_add(1, Ordering::Relaxed);
        Ok(built)
    }

    /// Calibration-triggered plan swaps performed over this registry's
    /// lifetime.
    pub fn replans(&self) -> u64 {
        self.replans.load(Ordering::Relaxed)
    }

    /// Number of cached plans.
    pub fn len(&self) -> usize {
        self.plans.lock().unwrap().len()
    }

    /// Every cached plan, in `(device, variant)` key order — the
    /// metrics report reads this to surface the chosen per-device pass
    /// schedules.
    pub fn cached(&self) -> Vec<Arc<ExecutionPlan>> {
        self.plans.lock().unwrap().values().cloned().collect()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for PlanRegistry {
    fn default() -> Self {
        PlanRegistry::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::registry::device_spec;

    #[test]
    fn gpu_class_plan_reaches_full_coverage_and_beats_unplanned() {
        let rules = RuleSet::default();
        let spec = device_spec("adreno740").unwrap();
        let g = model::unet_graph("base").unwrap();
        let before = modeled_cost_s(&g, &rules, &spec);
        let planned = plan_graph(&g, &rules, &spec);
        assert_eq!(planned.coverage, 1.0, "passes fix every island: {:?}", planned.passes_used);
        assert!(
            planned.cost_s < before,
            "islands cost transfers: {} !< {}",
            planned.cost_s,
            before
        );
        assert!(planned.passes_used.contains(&"groupnorm"));
        assert!(planned.passes_used.contains(&"serialize_conv"));
        planned.graph.validate().unwrap();
    }

    #[test]
    fn single_device_class_rejects_pointless_serialization() {
        let rules = RuleSet::default();
        let spec = device_spec("custom").unwrap();
        let g = model::unet_graph("base").unwrap();
        let before = modeled_cost_s(&g, &rules, &spec);
        let planned = plan_graph(&g, &rules, &spec);
        // complete-coverage kernels never pay to serialize (the split
        // only loses the Winograd reduction and adds partial sums)
        assert!(!planned.passes_used.contains(&"serialize_conv"), "{:?}", planned.passes_used);
        assert!(planned.cost_s <= before);
    }

    #[test]
    fn plans_predict_faster_service_on_the_faster_class() {
        let reg = PlanRegistry::new();
        let fast = reg.plan(&device_spec("adreno740").unwrap(), "mobile").unwrap();
        let slow = reg.plan(&device_spec("bigcore").unwrap(), "mobile").unwrap();
        assert!(fast.step_latency_s < slow.step_latency_s);
        assert!(fast.predict_service_s(20) < slow.predict_service_s(20));
        // more steps cost more
        assert!(fast.predict_service_s(20) > fast.predict_service_s(4));
        assert!(fast.peak_memory > 0 && slow.peak_memory > 0);
    }

    #[test]
    fn schedules_record_the_fusions_where_the_gate_accepts_them() {
        let rules = RuleSet::default();
        // on the GPU-delegate class the full base pipeline lands,
        // fusions included: the coverage passes reach 1.0 first, and
        // the fusions then strictly cut dispatches/traffic
        let spec = device_spec("adreno740").unwrap();
        let g = model::unet_graph("base").unwrap();
        let planned = plan_graph(&g, &rules, &spec);
        assert!(planned.passes_used.contains(&"fused_softmax"), "{:?}", planned.passes_used);
        assert!(
            planned.passes_used.contains(&"attention_reshape_elim"),
            "{:?}",
            planned.passes_used
        );
        // the schedule preserves registry order
        let order = crate::passes::PassRegistry::standard().names();
        let mut last = 0usize;
        for name in &planned.passes_used {
            let idx = order.iter().position(|n| n == name).unwrap();
            assert!(idx >= last, "schedule out of registry order: {:?}", planned.passes_used);
            last = idx;
        }
    }

    #[test]
    fn registry_caches_per_device_and_variant() {
        let reg = PlanRegistry::new();
        assert!(reg.is_empty());
        let spec = device_spec("adreno740").unwrap();
        let a = reg.plan(&spec, "mobile").unwrap();
        let b = reg.plan(&spec, "mobile").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lookup is a cache hit");
        assert_eq!(reg.len(), 1);
        reg.plan(&spec, "base").unwrap();
        reg.plan(&device_spec("bigcore").unwrap(), "mobile").unwrap();
        assert_eq!(reg.len(), 3);
        assert!(reg.plan(&spec, "huge").is_err(), "unknown variant");
    }

    #[test]
    fn base_variant_costs_more_than_mobile_on_the_gpu_class() {
        let reg = PlanRegistry::new();
        let spec = device_spec("adreno740").unwrap();
        let base = reg.plan(&spec, "base").unwrap();
        let mobile = reg.plan(&spec, "mobile").unwrap();
        assert!(base.step_latency_s > mobile.step_latency_s, "squeezing pays");
    }
}
