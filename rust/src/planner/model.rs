//! Representative component graphs the planner prices.
//!
//! The runtime ships components as opaque AOT-lowered HLO, which the
//! delegate simulator cannot partition; what it *can* partition is a
//! TFLite-level graph.  This module builds small, SD-flavored stand-in
//! graphs per variant carrying exactly the pathologies the paper
//! analyzes — the naive group-norm island (rank-5 + BroadcastTo), the
//! over-capacity 1920->640 3x3 conv at 32x32, and the 4096-row
//! fully-connected — plus the attention-export debris the follow-up
//! mobile-diffusion work targets (a decomposed exp/sum/div softmax
//! island and cancelling Reshape/Transpose pairs around the
//! BatchMatmuls), so `plan_graph` reproduces the paper's coverage
//! and latency structure per device class.  The graphs are costing
//! models, not executables: absolute sizes are scaled down, relative
//! shapes (and therefore which delegate rules fire) are faithful.

use crate::error::{Error, Result};
use crate::graph::builder::GraphBuilder;
use crate::graph::Graph;

/// Every variant the planner can price — the single source of truth
/// for "which variants exist" (startup pre-pricing iterates this).
pub const VARIANTS: &[&str] = &["base", "mobile"];

/// UNet stand-in for a variant: `base` keeps the paper's failure
/// shapes (delegate-breaking conv + FC), `mobile` is the squeezed
/// variant whose shapes pass the rules outright.
pub fn unet_graph(variant: &str) -> Result<Graph> {
    match variant {
        "base" => Ok(unet_base()),
        "mobile" => Ok(unet_mobile()),
        other => Err(Error::Config(format!(
            "planner has no model graph for variant '{other}' (known: {})",
            VARIANTS.join(", ")
        ))),
    }
}

fn unet_base() -> Graph {
    let mut b = GraphBuilder::new("unet_base");
    let x = b.input("latent", &[1, 32, 32, 1920]);
    let h = b.group_norm_naive("gn_in", x, 32);
    // the paper's exactly-one failing conv: C_in 1920 and 2.62M elems
    let h = b.conv2d("bottleneck", h, 640, 3, 1);
    let h = b.conv2d("proj_in", h, 320, 1, 1);
    // export-form self-attention at 1024 tokens, carrying the
    // decomposed softmax island and the exporter's cancelling
    // Reshape/Transpose layout debris (the fused_softmax /
    // attention_reshape_elim targets)
    let t = b.reshape("attn_tokens", h, &[1, 1024, 320]);
    let t = b.attention("attn", t, 4);
    let h = b.reshape("attn_untokens", t, &[1, 32, 32, 320]);
    // FF block on 4096 tokens: rows > fc_max_rows fails
    let t = b.reshape("tokens", h, &[1, 4096, 80]);
    let t = b.fully_connected("ff1", t, 320);
    let t = b.gelu("gelu", t, false);
    let t = b.fully_connected("ff2", t, 80);
    let h = b.reshape("untokens", t, &[1, 32, 32, 320]);
    let h = b.group_norm_naive("gn_out", h, 32);
    b.conv2d("proj_out", h, 4, 3, 1);
    b.finish()
}

fn unet_mobile() -> Graph {
    let mut b = GraphBuilder::new("unet_mobile");
    let x = b.input("latent", &[1, 32, 32, 960]);
    let h = b.group_norm_naive("gn_in", x, 32);
    // squeezed: C_in under the arena limit, conv delegates outright
    let h = b.conv2d("bottleneck", h, 320, 3, 1);
    let h = b.conv2d("proj_in", h, 320, 1, 1);
    // 1024 tokens: under fc_max_rows, FC delegates outright; the
    // squeezed variant keeps the same export-form attention debris
    let t = b.reshape("tokens", h, &[1, 1024, 320]);
    let t = b.attention("attn", t, 4);
    let t = b.fully_connected("ff1", t, 1280);
    let t = b.gelu("gelu", t, false);
    let t = b.fully_connected("ff2", t, 320);
    let h = b.reshape("untokens", t, &[1, 32, 32, 320]);
    let h = b.group_norm_naive("gn_out", h, 32);
    b.conv2d("proj_out", h, 4, 3, 1);
    b.finish()
}

/// Text-encoder stand-in (77-token context, FF-dominated).
pub fn text_encoder_graph() -> Graph {
    let mut b = GraphBuilder::new("text_encoder");
    let x = b.input("tokens", &[1, 77, 512]);
    let h = b.fully_connected("ff1", x, 2048);
    let h = b.gelu("gelu", h, false);
    b.fully_connected("ff2", h, 512);
    b.finish()
}

/// VAE-decoder stand-in (conv-dominated, one group-norm island).
pub fn decoder_graph() -> Graph {
    let mut b = GraphBuilder::new("decoder");
    let x = b.input("latent", &[1, 32, 32, 4]);
    let h = b.conv2d("conv_in", x, 128, 3, 1);
    let h = b.group_norm_naive("gn", h, 32);
    let h = b.conv2d("conv_mid", h, 128, 3, 1);
    b.conv2d("conv_out", h, 3, 1, 1);
    b.finish()
}

/// The full component set the serving stack runs per request:
/// `(unet, text_encoder, decoder)`.
pub fn component_graphs(variant: &str) -> Result<(Graph, Graph, Graph)> {
    Ok((unet_graph(variant)?, text_encoder_graph(), decoder_graph()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delegate::RuleSet;

    #[test]
    fn model_graphs_are_valid_and_carry_the_paper_pathologies() {
        let rules = RuleSet::default();
        let (base, text, dec) = component_graphs("base").unwrap();
        base.validate().unwrap();
        text.validate().unwrap();
        dec.validate().unwrap();
        // base keeps the paper's failures: coverage well below 1
        assert!(rules.coverage(&base) < 1.0);
        let fails = rules.failures(&base);
        assert!(
            fails.iter().any(|(op, _)| op.name == "bottleneck"),
            "the 1920->640 conv must fail the delegate rules"
        );
        assert!(
            fails.iter().any(|(op, _)| op.name == "ff1"),
            "the 4096-row FC must fail the delegate rules"
        );

        let (mobile, _, _) = component_graphs("mobile").unwrap();
        mobile.validate().unwrap();
        // mobile's conv/FC shapes pass outright; only the group-norm
        // islands remain for the pass pipeline
        assert!(!rules
            .failures(&mobile)
            .iter()
            .any(|(op, _)| op.name == "bottleneck" || op.name == "ff1"));
    }

    #[test]
    fn unets_carry_the_attention_export_debris() {
        use crate::graph::OpType;
        for variant in VARIANTS {
            let g = unet_graph(variant).unwrap();
            let hist = g.op_histogram();
            // the decomposed softmax island...
            assert_eq!(hist[&OpType::Exp], 1, "{variant}");
            assert_eq!(hist[&OpType::Sum], 1, "{variant}");
            assert_eq!(hist[&OpType::Div], 1, "{variant}");
            // ...and the cancelling layout pairs around the matmuls
            assert_eq!(hist[&OpType::BatchMatmul], 2, "{variant}");
            assert!(hist[&OpType::Transpose] >= 2, "{variant}");
            // nothing pre-fused in the export form
            assert_eq!(hist.get(&OpType::FusedSoftmax), None, "{variant}");
        }
    }

    #[test]
    fn unknown_variant_is_an_error() {
        assert!(unet_graph("huge").is_err());
    }
}
