//! Named device-class registry: every device profile the cost model
//! ships is reachable by name from the CLI (`--device`) and the serving
//! fleet spec (`--fleet`).
//!
//! A *device class* is a phone SoC seen from the delegate's point of
//! view: the accelerator the delegate targets plus (for the TFLite
//! GPU-delegate path) the CPU that absorbs non-delegable islands.  The
//! comparator classes (Hexagon NPU, custom OpenCL kernels) execute the
//! whole graph on one device — complete coverage by construction, no
//! fallback — matching how the paper's Table 1 baselines ran.

use crate::delegate::{
    DeviceProfile, CPU_BIGCORE, GPU_ADRENO740, GPU_CUSTOM_KERNELS, NPU_HEXAGON,
};

/// A schedulable device class: the delegate target plus its CPU
/// fallback (None = single-device execution, complete coverage).
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    /// registry name (CLI `--device`, fleet spec `--fleet name:count`)
    pub name: &'static str,
    /// the accelerator the delegate dispatches to
    pub delegate: DeviceProfile,
    /// CPU absorbing non-delegable islands; `None` runs everything on
    /// `delegate` (comparator classes with full coverage by construction)
    pub fallback: Option<DeviceProfile>,
    pub description: &'static str,
}

impl DeviceSpec {
    pub fn is_single_device(&self) -> bool {
        self.fallback.is_none()
    }
}

/// Every shipped device class, in fleet-spec order of "capability":
/// the paper's primary target first, comparators after.
pub fn registered_devices() -> Vec<DeviceSpec> {
    vec![
        DeviceSpec {
            name: "adreno740",
            delegate: GPU_ADRENO740,
            fallback: Some(CPU_BIGCORE),
            description: "Galaxy-S23-class phone: TFLite GPU delegate on an \
                          Adreno-740, XNNPACK big-core CPU fallback",
        },
        DeviceSpec {
            name: "bigcore",
            delegate: CPU_BIGCORE,
            fallback: None,
            description: "CPU-only phone: XNNPACK fp16 on Snapdragon big \
                          cores, every op supported",
        },
        DeviceSpec {
            name: "hexagon",
            delegate: NPU_HEXAGON,
            fallback: None,
            description: "Hexagon-class NPU comparator (Hou & Asghar): \
                          complete coverage, lower sustained efficiency",
        },
        DeviceSpec {
            name: "custom",
            delegate: GPU_CUSTOM_KERNELS,
            fallback: None,
            description: "custom OpenCL kernels comparator (Chen et al.): \
                          complete coverage by construction",
        },
    ]
}

/// Look a device class up by registry name.
pub fn device_spec(name: &str) -> Option<DeviceSpec> {
    registered_devices().into_iter().find(|d| d.name == name)
}

/// All registry names, in `registered_devices` order.
pub fn device_names() -> Vec<&'static str> {
    registered_devices().iter().map(|d| d.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_shipped_profile_is_reachable_by_name() {
        // the four delegate constants each back exactly one named class
        let adreno = device_spec("adreno740").unwrap();
        assert_eq!(adreno.delegate.name, GPU_ADRENO740.name);
        assert_eq!(adreno.fallback.as_ref().unwrap().name, CPU_BIGCORE.name);

        let cpu = device_spec("bigcore").unwrap();
        assert_eq!(cpu.delegate.name, CPU_BIGCORE.name);
        assert!(cpu.is_single_device());

        let npu = device_spec("hexagon").unwrap();
        assert_eq!(npu.delegate.name, NPU_HEXAGON.name);
        assert!(npu.is_single_device());

        let custom = device_spec("custom").unwrap();
        assert_eq!(custom.delegate.name, GPU_CUSTOM_KERNELS.name);
        assert!(custom.is_single_device());
    }

    #[test]
    fn names_round_trip_and_unknown_is_none() {
        for name in device_names() {
            let spec = device_spec(name).unwrap();
            assert_eq!(spec.name, name);
        }
        assert!(device_spec("adreno999").is_none());
        assert_eq!(device_names().len(), registered_devices().len());
    }
}
