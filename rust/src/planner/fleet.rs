//! Heterogeneous fleet description and plan-driven admission routing.
//!
//! A fleet spec names the device classes behind one queue —
//! `adreno740:2,bigcore:1` is two GPU-delegate phones plus one
//! CPU-only phone — resolved against the planner's profile registry.
//! The router turns a submission's `(variant, steps, deadline)` into a
//! worker-class assignment using plan-predicted service times:
//!
//! * a class is **feasible** when its predicted service time fits the
//!   deadline (deadline-less requests are routed against the queue's
//!   aging horizon, [`FALLBACK_DEADLINE`]);
//! * among feasible classes the **cheapest** wins — the *slowest*
//!   device that still meets the deadline, keeping fast silicon free
//!   for the requests that actually need it;
//! * a deadline no class can meet is rejected **at admission** instead
//!   of expiring in the queue; deadline-less requests are never
//!   rejected (the fastest class takes them as a last resort).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::queue::FALLBACK_DEADLINE;
use crate::error::{Error, Result};

use super::calibrate::{FleetCalibration, REPLAN_DIVERGENCE};
use super::plan::PlanRegistry;
use super::registry::{device_names, device_spec, DeviceSpec};

/// One class of identical workers in the fleet.
#[derive(Debug, Clone)]
pub struct WorkerClassSpec {
    pub device: DeviceSpec,
    pub count: usize,
}

/// The whole fleet, class order = spec order (= pool class indices).
#[derive(Debug, Clone)]
pub struct FleetSpec {
    pub classes: Vec<WorkerClassSpec>,
}

impl FleetSpec {
    /// Parse `name:count,name:count,...` (a bare `name` means one
    /// worker).  Names resolve against the profile registry; unknown
    /// names, zero counts, and duplicate classes are errors.
    pub fn parse(s: &str) -> Result<FleetSpec> {
        let mut classes: Vec<WorkerClassSpec> = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (name, count) = match part.split_once(':') {
                Some((n, c)) => {
                    let count: usize = c.trim().parse().map_err(|e| {
                        Error::Config(format!("fleet spec '{part}': bad count: {e}"))
                    })?;
                    (n.trim(), count)
                }
                None => (part, 1),
            };
            if count == 0 {
                return Err(Error::Config(format!(
                    "fleet spec '{part}': count must be at least 1"
                )));
            }
            let device = device_spec(name).ok_or_else(|| {
                Error::Config(format!(
                    "fleet spec: unknown device '{name}' (known: {})",
                    device_names().join(", ")
                ))
            })?;
            if classes.iter().any(|c| c.device.name == device.name) {
                return Err(Error::Config(format!(
                    "fleet spec: device class '{name}' listed twice"
                )));
            }
            classes.push(WorkerClassSpec { device, count });
        }
        if classes.is_empty() {
            return Err(Error::Config(
                "fleet spec names no device classes (e.g. adreno740:2,bigcore:1)".into(),
            ));
        }
        Ok(FleetSpec { classes })
    }

    pub fn total_workers(&self) -> usize {
        self.classes.iter().map(|c| c.count).sum()
    }

    /// Registry names in class order (pool class index order).
    pub fn class_names(&self) -> Vec<String> {
        self.classes.iter().map(|c| c.device.name.to_string()).collect()
    }
}

/// A routing decision for one admitted request.
#[derive(Debug, Clone, Copy)]
pub struct Route {
    /// index into the fleet's class list (= pool class index)
    pub class: usize,
    /// plan-predicted service time on that class, seconds
    pub predicted_s: f64,
}

/// Plan-driven admission router over one fleet.
#[derive(Debug)]
pub struct FleetRouter {
    fleet: FleetSpec,
    plans: Arc<PlanRegistry>,
    /// shared per-class roofline calibration (None = routing runs on
    /// shipped constants forever)
    calibration: Option<FleetCalibration>,
    /// divergence each class's cached plans were last built under —
    /// the hysteresis state of the re-plan trigger
    applied: Mutex<BTreeMap<String, f64>>,
}

impl FleetRouter {
    pub fn new(fleet: FleetSpec, plans: Arc<PlanRegistry>) -> FleetRouter {
        FleetRouter { fleet, plans, calibration: None, applied: Mutex::new(BTreeMap::new()) }
    }

    /// A router whose plans track a shared calibration stream: call
    /// [`FleetRouter::apply_calibration`] periodically (the metrics
    /// report does) to fold fitted models back into the plan cache.
    pub fn with_calibration(
        fleet: FleetSpec,
        plans: Arc<PlanRegistry>,
        calibration: FleetCalibration,
    ) -> FleetRouter {
        FleetRouter {
            fleet,
            plans,
            calibration: Some(calibration),
            applied: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn fleet(&self) -> &FleetSpec {
        &self.fleet
    }

    pub fn plans(&self) -> &Arc<PlanRegistry> {
        &self.plans
    }

    pub fn calibration(&self) -> Option<&FleetCalibration> {
        self.calibration.as_ref()
    }

    /// Fold the calibration stream back into the plan cache: for every
    /// fleet class whose fitted model has moved more than
    /// [`REPLAN_DIVERGENCE`] away from the model its cached plans were
    /// built under, rebuild those `(device, variant)` plans against the
    /// fitted overlay.  Returns human-readable lines describing what
    /// was re-planned (empty when nothing crossed the threshold) — the
    /// metrics report prints them verbatim.
    pub fn apply_calibration(&self) -> Vec<String> {
        let Some(cal) = &self.calibration else {
            return Vec::new();
        };
        let mut lines = Vec::new();
        let cached = self.plans.cached();
        let mut applied = self.applied.lock().unwrap();
        for class in &self.fleet.classes {
            let name = class.device.name;
            let Some(profile) = cal.profile(name) else { continue };
            if !profile.is_calibrated() {
                continue;
            }
            let div = profile.divergence();
            let last = applied.get(name).copied().unwrap_or(0.0);
            if (div - last).abs() <= REPLAN_DIVERGENCE {
                continue;
            }
            let variants: Vec<String> = cached
                .iter()
                .filter(|p| p.device == name)
                .map(|p| p.variant.clone())
                .collect();
            let mut class_lines = Vec::new();
            let mut replanned = 0usize;
            for variant in &variants {
                match self.plans.replan(&class.device, variant, &profile) {
                    Ok(p) => {
                        replanned += 1;
                        class_lines.push(format!(
                            "  replanned {}/{}: step {:.3} ms, w8a8 {}",
                            name,
                            variant,
                            p.step_latency_s * 1e3,
                            if p.w8a8 { "on" } else { "off" },
                        ));
                    }
                    Err(e) => class_lines.push(format!("  replan {name}/{variant} failed: {e}")),
                }
            }
            if replanned > 0 {
                applied.insert(name.to_string(), div);
                lines.push(format!(
                    "calibration {name}: divergence {:.0}% (plans built at {:.0}%), {} obs",
                    div * 100.0,
                    last * 100.0,
                    cal.observations(name),
                ));
                lines.extend(class_lines);
            }
        }
        lines
    }

    /// Plan-predicted service time of `(variant, num_steps)` on a class.
    pub fn predicted_s(&self, class: usize, variant: &str, num_steps: usize) -> Result<f64> {
        let c = self.fleet.classes.get(class).ok_or_else(|| {
            Error::Config(format!("no fleet class {class}"))
        })?;
        Ok(self.plans.plan(&c.device, variant)?.predict_service_s(num_steps))
    }

    /// Pick the cheapest feasible class (see module docs).  Returns
    /// [`Error::Queue`] when a deadline is infeasible on every class.
    pub fn route(
        &self,
        variant: &str,
        num_steps: usize,
        deadline: Option<Duration>,
    ) -> Result<Route> {
        self.route_observed(variant, num_steps, deadline, &|_| None)
    }

    /// Routing with measured-overhead feedback: `observed_overhead(i)`
    /// supplies device class `i`'s mean measured per-request overhead
    /// (loads + encode + decode), which replaces the plan's modeled
    /// constant in the service-time prediction once available — so
    /// admission decisions track what the fleet actually pays on its
    /// load path (e.g. cheap warm reloads after the first requests)
    /// rather than the cost model's bootstrap estimate.
    pub fn route_observed(
        &self,
        variant: &str,
        num_steps: usize,
        deadline: Option<Duration>,
        observed_overhead: &dyn Fn(usize) -> Option<f64>,
    ) -> Result<Route> {
        self.route_observed_filtered(variant, num_steps, deadline, observed_overhead, &|_| true)
    }

    /// Routing under degrading admission: classes for which
    /// `admit(class)` is false (quarantined by their circuit breaker)
    /// are skipped as if absent from the fleet.  A deadline only the
    /// quarantined classes could meet is rejected as infeasible — the
    /// healthy fleet is what the prediction has to hold on.  When
    /// *every* class is filtered out the request is refused outright
    /// (callers shed or queue it at their own policy).
    pub fn route_observed_filtered(
        &self,
        variant: &str,
        num_steps: usize,
        deadline: Option<Duration>,
        observed_overhead: &dyn Fn(usize) -> Option<f64>,
        admit: &dyn Fn(usize) -> bool,
    ) -> Result<Route> {
        self.route_pressure_filtered(
            variant,
            num_steps,
            deadline,
            observed_overhead,
            admit,
            &|_| None,
        )
    }

    /// Routing under memory pressure as well: `headroom(class)`
    /// supplies the class's *learned* effective memory budget in bytes
    /// (`None` = no governor watching that class).  A class whose
    /// plan's `peak_memory` no longer fits its learned budget is
    /// skipped like a quarantined one — the request reroutes to a
    /// class with real headroom instead of being fed to an allocator
    /// the governor already saw exhaust.  When memory filtering alone
    /// rejected every class the error says so.
    pub fn route_pressure_filtered(
        &self,
        variant: &str,
        num_steps: usize,
        deadline: Option<Duration>,
        observed_overhead: &dyn Fn(usize) -> Option<f64>,
        admit: &dyn Fn(usize) -> bool,
        headroom: &dyn Fn(usize) -> Option<usize>,
    ) -> Result<Route> {
        let horizon = deadline.unwrap_or(FALLBACK_DEADLINE).as_secs_f64();
        let mut cheapest: Option<Route> = None;
        let mut fastest: Option<Route> = None;
        let mut over_budget = 0usize;
        for (i, class) in self.fleet.classes.iter().enumerate() {
            if !admit(i) {
                continue;
            }
            let plan = self.plans.plan(&class.device, variant)?;
            if let Some(budget) = headroom(i) {
                if plan.peak_memory > budget {
                    over_budget += 1;
                    continue;
                }
            }
            let predicted_s = plan.predict_service_with(num_steps, observed_overhead(i));
            if fastest.map_or(true, |f: Route| predicted_s < f.predicted_s) {
                fastest = Some(Route { class: i, predicted_s });
            }
            let is_cheaper = match cheapest {
                Some(c) => predicted_s > c.predicted_s,
                None => true,
            };
            if predicted_s <= horizon && is_cheaper {
                cheapest = Some(Route { class: i, predicted_s });
            }
        }
        let Some(fastest) = fastest else {
            if over_budget > 0 {
                return Err(Error::Queue(format!(
                    "no admitted device class has memory headroom for '{variant}': \
                     {over_budget} over their learned budget, the rest quarantined"
                )));
            }
            return Err(Error::Queue(format!(
                "every device class is quarantined; no route for {num_steps} steps \
                 of '{variant}'"
            )));
        };
        match cheapest {
            Some(route) => Ok(route),
            // deadline-less work is never rejected: fall back to the
            // fastest admitted class even past the aging horizon
            None if deadline.is_none() => Ok(fastest),
            None => Err(Error::Queue(format!(
                "deadline {:.3}s infeasible: fastest class '{}' predicts {:.3}s \
                 for {num_steps} steps of '{variant}'",
                horizon,
                self.fleet.classes[fastest.class].device.name,
                fastest.predicted_s,
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_counts_and_bare_names() {
        let f = FleetSpec::parse("adreno740:2,bigcore:1").unwrap();
        assert_eq!(f.total_workers(), 3);
        assert_eq!(f.class_names(), vec!["adreno740", "bigcore"]);

        let f = FleetSpec::parse("hexagon").unwrap();
        assert_eq!(f.total_workers(), 1);
        assert_eq!(f.classes[0].device.name, "hexagon");
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(FleetSpec::parse("").is_err(), "empty");
        assert!(FleetSpec::parse("warpdrive:1").is_err(), "unknown device");
        assert!(FleetSpec::parse("adreno740:0").is_err(), "zero count");
        assert!(FleetSpec::parse("adreno740:x").is_err(), "bad count");
        assert!(FleetSpec::parse("adreno740:1,adreno740:2").is_err(), "duplicate");
    }

    fn two_class_router() -> FleetRouter {
        let fleet = FleetSpec::parse("adreno740:1,bigcore:1").unwrap();
        FleetRouter::new(fleet, Arc::new(PlanRegistry::new()))
    }

    #[test]
    fn tight_deadlines_route_to_the_fast_class_lax_to_the_cheap_one() {
        let r = two_class_router();
        let fast = r.predicted_s(0, "mobile", 20).unwrap();
        let slow = r.predicted_s(1, "mobile", 20).unwrap();
        assert!(fast < slow, "adreno {fast} vs bigcore {slow}");

        // between the two predictions: only the GPU class is feasible
        let tight = Duration::from_secs_f64((fast + slow) / 2.0);
        let route = r.route("mobile", 20, Some(tight)).unwrap();
        assert_eq!(route.class, 0);
        assert!((route.predicted_s - fast).abs() < 1e-12);

        // past both predictions: the slower class is the cheaper pick
        let lax = Duration::from_secs_f64(slow * 2.0);
        assert_eq!(r.route("mobile", 20, Some(lax)).unwrap().class, 1);

        // no deadline: routed against the aging horizon, cheapest wins
        assert_eq!(r.route("mobile", 20, None).unwrap().class, 1);
    }

    #[test]
    fn infeasible_deadlines_are_rejected_with_the_fastest_prediction() {
        let r = two_class_router();
        let fast = r.predicted_s(0, "mobile", 20).unwrap();
        let err = r
            .route("mobile", 20, Some(Duration::from_secs_f64(fast / 2.0)))
            .unwrap_err();
        assert!(err.to_string().contains("infeasible"), "{err}");
        assert!(err.to_string().contains("adreno740"), "{err}");
    }

    #[test]
    fn observed_overhead_feedback_changes_the_routing_decision() {
        let r = two_class_router();
        let fast = r.predicted_s(0, "mobile", 20).unwrap();
        let slow = r.predicted_s(1, "mobile", 20).unwrap();
        let slow_plan = r
            .plans()
            .plan(&r.fleet().classes[1].device, "mobile")
            .unwrap();
        // deadline strictly between the slow class's step-only time
        // and its full modeled prediction
        let d = (slow - slow_plan.overhead_s) + slow_plan.overhead_s / 2.0;
        assert!(fast < d, "precondition: the fast class always fits ({fast} vs {d})");
        assert!(
            slow - slow_plan.overhead_s > fast,
            "precondition: even overhead-free, the slow class stays the cheaper pick"
        );
        let deadline = Duration::from_secs_f64(d);

        // bootstrap model: the slow class misses the deadline by half
        // its modeled overhead, so the fast class takes the request
        assert_eq!(r.route("mobile", 20, Some(deadline)).unwrap().class, 0);

        // measured feedback: the slow class's observed overhead is ~0
        // (store hits + warm reloads), making it feasible — and being
        // the cheaper class, it now wins the same request
        let observed = |class: usize| if class == 1 { Some(0.0) } else { None };
        let route = r
            .route_observed("mobile", 20, Some(deadline), &observed)
            .unwrap();
        assert_eq!(route.class, 1, "measured overhead re-routed the request");
        assert!(route.predicted_s <= d);
    }

    #[test]
    fn quarantined_classes_are_routed_around_or_refused() {
        let r = two_class_router();
        let no_overhead = |_: usize| None;

        // un-filtered, a deadline-less request picks the cheap class 1
        assert_eq!(r.route("mobile", 20, None).unwrap().class, 1);
        // with class 1 quarantined, the same request lands on class 0
        let only_fast = |class: usize| class == 0;
        let route = r
            .route_observed_filtered("mobile", 20, None, &no_overhead, &only_fast)
            .unwrap();
        assert_eq!(route.class, 0, "quarantine rerouted the request");

        // a deadline only the fast (quarantined) class could meet is
        // infeasible on the healthy remainder
        let fast = r.predicted_s(0, "mobile", 20).unwrap();
        let slow = r.predicted_s(1, "mobile", 20).unwrap();
        let tight = Duration::from_secs_f64((fast + slow) / 2.0);
        let only_slow = |class: usize| class == 1;
        let err = r
            .route_observed_filtered("mobile", 20, Some(tight), &no_overhead, &only_slow)
            .unwrap_err();
        assert!(err.to_string().contains("infeasible"), "{err}");

        // every class quarantined: refused outright, even deadline-less
        let none = |_: usize| false;
        let err = r
            .route_observed_filtered("mobile", 20, None, &no_overhead, &none)
            .unwrap_err();
        assert!(err.to_string().contains("quarantined"), "{err}");
    }

    #[test]
    fn learned_memory_budgets_reroute_or_refuse() {
        let r = two_class_router();
        let no_overhead = |_: usize| None;
        let all = |_: usize| true;
        let peak = |class: usize| {
            r.plans()
                .plan(&r.fleet().classes[class].device, "mobile")
                .unwrap()
                .peak_memory
        };
        let (p0, p1) = (peak(0), peak(1));

        // budgets above both peaks change nothing
        let roomy = move |_: usize| Some(p0.max(p1) + 1);
        let base = r.route("mobile", 20, None).unwrap().class;
        let route = r
            .route_pressure_filtered("mobile", 20, None, &no_overhead, &all, &roomy)
            .unwrap();
        assert_eq!(route.class, base);

        // the cheap class's learned budget dropped below its peak:
        // the request reroutes to the class with headroom
        let squeezed = move |class: usize| if class == base { Some(p1.min(p0) / 2) } else { None };
        let route = r
            .route_pressure_filtered("mobile", 20, None, &no_overhead, &all, &squeezed)
            .unwrap();
        assert_ne!(route.class, base, "pressure rerouted the request");

        // every class over budget: refused with a memory message,
        // even deadline-less
        let none = |_: usize| Some(0usize);
        let err = r
            .route_pressure_filtered("mobile", 20, None, &no_overhead, &all, &none)
            .unwrap_err();
        assert!(err.to_string().contains("memory headroom"), "{err}");
        assert!(matches!(err, Error::Queue(_)), "{err}");
    }

    #[test]
    fn unknown_variant_is_a_config_error_not_infeasibility() {
        let r = two_class_router();
        let err = r.route("huge", 20, None).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
    }

    #[test]
    fn calibration_replans_and_reroutes_to_the_truly_cheapest_class() {
        use crate::delegate::OpClass;
        use crate::planner::calibrate::{FleetCalibration, Observation, MIN_CLASS_SAMPLES};

        let fleet = FleetSpec::parse("adreno740:1,bigcore:1").unwrap();
        let cal = FleetCalibration::with_window(128);
        let r = FleetRouter::with_calibration(fleet, Arc::new(PlanRegistry::new()), cal.clone());

        let fast = r.predicted_s(0, "mobile", 20).unwrap();
        let slow = r.predicted_s(1, "mobile", 20).unwrap();
        let tight = Duration::from_secs_f64((fast + slow) / 2.0);
        // under shipped constants only the GPU class fits the deadline,
        // so the request is (mis)routed to the expensive fast silicon
        assert_eq!(r.route("mobile", 20, Some(tight)).unwrap().class, 0);
        // with nothing recorded, applying calibration is a no-op
        assert!(r.apply_calibration().is_empty());

        // the CPU silicon actually runs 4x better than the shipped
        // guess on every op class: synthesize roofline-exact dispatch
        // observations from the true triple
        let base = r.fleet().classes[1].device.delegate.clone();
        let (tf, tb, td) = (base.flops * 4.0, base.bandwidth * 4.0, base.dispatch / 4.0);
        for &class in OpClass::ALL {
            for i in 0..(3 * MIN_CLASS_SAMPLES) {
                let (flops, bytes) = match i % 3 {
                    0 => (1e9 * (1.0 + i as f64), 1e3),
                    1 => (1e3, 1e7 * (1.0 + i as f64)),
                    _ => (1e3, 1e3),
                };
                let seconds = td + (flops / tf).max(bytes / tb);
                cal.record("bigcore", &base, Observation { class, flops, bytes, seconds });
            }
        }

        let lines = r.apply_calibration();
        assert!(
            lines.iter().any(|l| l.contains("calibration bigcore")),
            "replan trigger fired: {lines:?}"
        );
        let slow_cal = r.predicted_s(1, "mobile", 20).unwrap();
        assert!(slow_cal < slow / 2.0, "calibrated plan is much cheaper: {slow_cal} vs {slow}");
        assert!(slow_cal > fast, "the CPU class stays the cheaper (slower) silicon");

        // same request, same deadline: the truly-cheapest class now
        // wins because the measured model says it is feasible
        let route = r.route("mobile", 20, Some(tight)).unwrap();
        assert_eq!(route.class, 1, "calibration flipped the routing decision");
        assert!(route.predicted_s <= tight.as_secs_f64());

        // hysteresis: a second application with no new evidence is quiet
        assert!(r.apply_calibration().is_empty());
        assert!(r.plans().replans() >= 1);
    }
}
