//! Online roofline calibration: fit per-op-class device parameters
//! from the live dispatch stream.
//!
//! The shipped [`DeviceProfile`] constants are educated guesses; real
//! silicon sustains different effective rates per op class (a conv
//! pipeline and a reduction loop saturate different fractions of
//! peak), and thermal state moves them at runtime.  Each executor
//! dispatch emits an [`Observation`] — the op class it was dominated
//! by, the modeled work (flops, bytes), and the measured wall — into a
//! per-device-class [`Calibrator`], which keeps a bounded window per
//! op class and fits an effective roofline triple (flops rate,
//! bandwidth, dispatch overhead) by alternating classification and
//! re-estimation: under the current fit each observation is either
//! compute- or memory-bound, compute-bound samples re-estimate the
//! flops rate, memory-bound ones the bandwidth, and the residual
//! re-estimates the dispatch floor.  A few iterations converge for
//! roofline-shaped data (pinned by a property test).
//!
//! The result is a [`CalibratedProfile`]: the shipped profile overlaid
//! with fitted per-class triples, implementing
//! [`RooflineModel`] so every cost function
//! (`op_latency_on`, `plan_graph_cal`, `w8a8_gain`) prices against
//! measured numbers.  [`FleetCalibration`] is the shared handle the
//! executors write and the router reads; when a class's fitted model
//! diverges from what its plans were last built against by more than
//! [`REPLAN_DIVERGENCE`], `FleetRouter::apply_calibration` rebuilds
//! the affected `(device, variant)` plans so pass schedules, W8A8
//! gating and admission routing track the hardware.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::delegate::{DeviceProfile, OpClass, RoofParams, RooflineModel};

/// Default bounded window of observations kept per op class
/// (`--calib-window`).
pub const DEFAULT_CALIB_WINDOW: usize = 256;

/// Observations a class needs before its fit is trusted — below this
/// the shipped constants keep pricing the class.
pub const MIN_CLASS_SAMPLES: usize = 8;

/// Relative divergence between a fitted model and the model a plan was
/// built against beyond which the plan registry re-plans the pair.
pub const REPLAN_DIVERGENCE: f64 = 0.25;

/// Alternating-projection iterations of the windowed fit.
const FIT_ITERS: usize = 6;

/// One measured dispatch: the modeled work and the measured wall.
#[derive(Debug, Clone, Copy)]
pub struct Observation {
    pub class: OpClass,
    /// modeled FLOPs the dispatch performed
    pub flops: f64,
    /// modeled bytes the dispatch moved
    pub bytes: f64,
    /// measured wall seconds
    pub seconds: f64,
}

/// Bounded (flops, bytes, seconds) window for one op class.
#[derive(Debug, Clone, Default)]
struct ClassWindow {
    obs: VecDeque<(f64, f64, f64)>,
}

impl ClassWindow {
    fn push(&mut self, flops: f64, bytes: f64, seconds: f64, cap: usize) {
        if self.obs.len() >= cap.max(1) {
            self.obs.pop_front();
        }
        self.obs.push_back((flops, bytes, seconds));
    }
}

/// Fit one class window against roofline structure
/// `t = dispatch + max(flops/F, bytes/B)`.
fn fit_class(obs: &VecDeque<(f64, f64, f64)>, start: RoofParams) -> RoofParams {
    let mut p = start;
    for _ in 0..FIT_ITERS {
        // ratio estimators (Σwork / Σtime): the big samples dominate
        // both sums, so near-pure-dispatch observations cannot drag
        // the fitted rates the way a mean-of-rates would
        let (mut f_sum, mut f_work) = (0.0, 0.0);
        let (mut b_sum, mut b_work) = (0.0, 0.0);
        for &(f, b, t) in obs {
            let work = (t - p.dispatch).max(t * 1e-3).max(1e-12);
            // classify under the current fit
            let comp = f / p.flops.max(1e-9);
            let mem = b / p.bandwidth.max(1e-9);
            if comp <= 0.0 && mem <= 0.0 {
                continue;
            }
            if comp >= mem {
                f_sum += f;
                f_work += work;
            } else {
                b_sum += b;
                b_work += work;
            }
        }
        if f_sum > 0.0 && f_work > 0.0 {
            p.flops = (f_sum / f_work).max(1e-9);
        }
        if b_sum > 0.0 && b_work > 0.0 {
            p.bandwidth = (b_sum / b_work).max(1e-9);
        }
        // dispatch floor: read it off the dispatch-dominated samples
        // (modeled work under half the wall); when every sample is
        // work-dominated, fall back to the mean positive residual
        let (mut disp_sum, mut disp_n) = (0.0, 0.0);
        let mut resid_sum = 0.0;
        for &(f, b, t) in obs {
            let work = (f / p.flops).max(b / p.bandwidth);
            resid_sum += (t - work).max(0.0);
            if work < t * 0.5 {
                disp_sum += t - work;
                disp_n += 1.0;
            }
        }
        p.dispatch = if disp_n > 0.0 {
            (disp_sum / disp_n).max(0.0)
        } else {
            (resid_sum / obs.len().max(1) as f64).max(0.0)
        };
    }
    p
}

/// Windowed per-op-class roofline fitter for one device class.
#[derive(Debug, Clone)]
pub struct Calibrator {
    base: DeviceProfile,
    window: usize,
    min_samples: usize,
    classes: [ClassWindow; 6],
    total: u64,
}

impl Calibrator {
    pub fn new(base: DeviceProfile) -> Calibrator {
        Calibrator::with_window(base, DEFAULT_CALIB_WINDOW)
    }

    /// A calibrator keeping at most `window` observations per op class.
    pub fn with_window(base: DeviceProfile, window: usize) -> Calibrator {
        Calibrator {
            base,
            window: window.max(1),
            min_samples: MIN_CLASS_SAMPLES.min(window.max(1)),
            classes: Default::default(),
            total: 0,
        }
    }

    pub fn base(&self) -> &DeviceProfile {
        &self.base
    }

    /// Record one dispatch.  Non-finite or non-positive walls are
    /// dropped — a faulted dispatch carries no cost signal.
    pub fn record(&mut self, obs: Observation) {
        if !obs.seconds.is_finite()
            || obs.seconds <= 0.0
            || !obs.flops.is_finite()
            || !obs.bytes.is_finite()
            || obs.flops < 0.0
            || obs.bytes < 0.0
        {
            return;
        }
        self.classes[obs.class.index()].push(obs.flops, obs.bytes, obs.seconds, self.window);
        self.total += 1;
    }

    /// Observations accepted over this calibrator's lifetime (monotone;
    /// the windows themselves are bounded).
    pub fn observations(&self) -> u64 {
        self.total
    }

    /// Observations currently windowed for `class`.
    pub fn class_samples(&self, class: OpClass) -> usize {
        self.classes[class.index()].obs.len()
    }

    /// Fit the calibrated overlay: classes with at least
    /// `min_samples` windowed observations get fitted triples, the
    /// rest keep the shipped constants.
    pub fn fit(&self) -> CalibratedProfile {
        let shipped = RoofParams {
            flops: self.base.flops,
            bandwidth: self.base.bandwidth,
            dispatch: self.base.dispatch,
        };
        let mut fitted: [Option<RoofParams>; 6] = [None; 6];
        for class in OpClass::ALL {
            let w = &self.classes[class.index()];
            if w.obs.len() >= self.min_samples {
                fitted[class.index()] = Some(fit_class(&w.obs, shipped));
            }
        }
        CalibratedProfile { base: self.base.clone(), fitted }
    }
}

/// The shipped profile overlaid with per-op-class fitted triples.
#[derive(Debug, Clone)]
pub struct CalibratedProfile {
    base: DeviceProfile,
    fitted: [Option<RoofParams>; 6],
}

impl CalibratedProfile {
    /// An overlay with no fits — prices identically to `base`.
    pub fn uncalibrated(base: DeviceProfile) -> CalibratedProfile {
        CalibratedProfile { base, fitted: [None; 6] }
    }

    /// An overlay applying one fitted triple to *every* class (tests,
    /// benches, property generators).
    pub fn uniform(base: DeviceProfile, params: RoofParams) -> CalibratedProfile {
        CalibratedProfile { base, fitted: [Some(params); 6] }
    }

    pub fn fitted(&self, class: OpClass) -> Option<RoofParams> {
        self.fitted[class.index()]
    }

    /// Number of op classes with trusted fits.
    pub fn fitted_classes(&self) -> usize {
        self.fitted.iter().filter(|f| f.is_some()).count()
    }

    pub fn is_calibrated(&self) -> bool {
        self.fitted_classes() > 0
    }

    /// Largest relative deviation of any fitted parameter from the
    /// shipped constants — the re-plan trigger metric.  0 when nothing
    /// is fitted (or the fits agree exactly).
    pub fn divergence(&self) -> f64 {
        let rel = |fitted: f64, shipped: f64| {
            if shipped.abs() < 1e-12 {
                if fitted.abs() < 1e-12 {
                    0.0
                } else {
                    1.0
                }
            } else {
                (fitted - shipped).abs() / shipped.abs()
            }
        };
        let mut worst: f64 = 0.0;
        for f in self.fitted.iter().flatten() {
            worst = worst
                .max(rel(f.flops, self.base.flops))
                .max(rel(f.bandwidth, self.base.bandwidth))
                .max(rel(f.dispatch, self.base.dispatch));
        }
        worst
    }
}

impl RooflineModel for CalibratedProfile {
    fn base(&self) -> &DeviceProfile {
        &self.base
    }

    fn params(&self, class: OpClass) -> RoofParams {
        self.fitted[class.index()].unwrap_or(RoofParams {
            flops: self.base.flops,
            bandwidth: self.base.bandwidth,
            dispatch: self.base.dispatch,
        })
    }
}

/// Shared fleet-wide calibration state: one [`Calibrator`] per device
/// class, written by the executors (one observation per dispatch) and
/// read by the router when it decides whether to re-plan.  Cheap to
/// clone — all clones share the same state.
#[derive(Debug, Clone)]
pub struct FleetCalibration {
    inner: Arc<Mutex<BTreeMap<String, Calibrator>>>,
    window: usize,
}

impl FleetCalibration {
    pub fn new() -> FleetCalibration {
        FleetCalibration::with_window(DEFAULT_CALIB_WINDOW)
    }

    pub fn with_window(window: usize) -> FleetCalibration {
        FleetCalibration { inner: Arc::new(Mutex::new(BTreeMap::new())), window: window.max(1) }
    }

    pub fn window(&self) -> usize {
        self.window
    }

    /// Record one dispatch for `class_name` (registry device-class
    /// name), lazily creating its calibrator anchored at `base`.
    pub fn record(&self, class_name: &str, base: &DeviceProfile, obs: Observation) {
        let mut inner = self.inner.lock().unwrap();
        inner
            .entry(class_name.to_string())
            .or_insert_with(|| Calibrator::with_window(base.clone(), self.window))
            .record(obs);
    }

    /// Lifetime observation count for `class_name` (0 if never seen).
    pub fn observations(&self, class_name: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .get(class_name)
            .map(|c| c.observations())
            .unwrap_or(0)
    }

    /// The current fitted overlay for `class_name`, if any dispatches
    /// were recorded.  The overlay may still be uncalibrated (no class
    /// reached `MIN_CLASS_SAMPLES`).
    pub fn profile(&self, class_name: &str) -> Option<CalibratedProfile> {
        self.inner.lock().unwrap().get(class_name).map(|c| c.fit())
    }

    /// Class names with any recorded observations, sorted.
    pub fn class_names(&self) -> Vec<String> {
        self.inner.lock().unwrap().keys().cloned().collect()
    }
}

impl Default for FleetCalibration {
    fn default() -> Self {
        FleetCalibration::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delegate::GPU_ADRENO740;

    fn true_params() -> RoofParams {
        RoofParams { flops: 0.6e12, bandwidth: 12e9, dispatch: 40e-6 }
    }

    /// Exact roofline latency under `p`.
    fn latency(p: RoofParams, flops: f64, bytes: f64) -> f64 {
        p.dispatch + (flops / p.flops).max(bytes / p.bandwidth)
    }

    fn feed(cal: &mut Calibrator, p: RoofParams, n: usize) {
        for i in 0..n {
            // alternate compute-bound, memory-bound and near-pure
            // dispatch work so every parameter is identified
            let (flops, bytes) = match i % 3 {
                0 => (1e9 * (1.0 + i as f64), 1e3),
                1 => (1e3, 1e7 * (1.0 + i as f64)),
                _ => (1e3, 1e3),
            };
            cal.record(Observation {
                class: OpClass::Conv,
                flops,
                bytes,
                seconds: latency(p, flops, bytes),
            });
        }
    }

    #[test]
    fn fit_recovers_a_known_profile() {
        let mut cal = Calibrator::new(GPU_ADRENO740);
        let truth = true_params();
        feed(&mut cal, truth, 48);
        let prof = cal.fit();
        let fitted = prof.fitted(OpClass::Conv).expect("enough samples");
        assert!((fitted.flops - truth.flops).abs() / truth.flops < 0.05, "{fitted:?}");
        assert!(
            (fitted.bandwidth - truth.bandwidth).abs() / truth.bandwidth < 0.05,
            "{fitted:?}"
        );
        assert!(
            (fitted.dispatch - truth.dispatch).abs() / truth.dispatch < 0.25,
            "{fitted:?}"
        );
        // classes never observed keep the shipped constants
        assert!(prof.fitted(OpClass::Matmul).is_none());
        let p = prof.params(OpClass::Matmul);
        assert_eq!(p.flops, GPU_ADRENO740.flops);
    }

    #[test]
    fn below_min_samples_the_shipped_constants_hold() {
        let mut cal = Calibrator::new(GPU_ADRENO740);
        feed(&mut cal, true_params(), MIN_CLASS_SAMPLES - 1);
        let prof = cal.fit();
        assert!(!prof.is_calibrated());
        assert_eq!(prof.divergence(), 0.0);
        let p = prof.params(OpClass::Conv);
        assert_eq!(p.bandwidth, GPU_ADRENO740.bandwidth);
    }

    #[test]
    fn windows_are_bounded_and_slide() {
        let mut cal = Calibrator::with_window(GPU_ADRENO740, 16);
        feed(&mut cal, true_params(), 500);
        assert_eq!(cal.class_samples(OpClass::Conv), 16);
        assert_eq!(cal.observations(), 500);
    }

    #[test]
    fn bogus_observations_are_dropped() {
        let mut cal = Calibrator::new(GPU_ADRENO740);
        for seconds in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            cal.record(Observation { class: OpClass::Conv, flops: 1.0, bytes: 1.0, seconds });
        }
        cal.record(Observation {
            class: OpClass::Conv,
            flops: f64::NAN,
            bytes: 1.0,
            seconds: 1.0,
        });
        assert_eq!(cal.observations(), 0);
    }

    #[test]
    fn divergence_grows_with_the_gap_from_shipped() {
        let close = CalibratedProfile::uniform(
            GPU_ADRENO740,
            RoofParams {
                flops: GPU_ADRENO740.flops * 1.01,
                bandwidth: GPU_ADRENO740.bandwidth,
                dispatch: GPU_ADRENO740.dispatch,
            },
        );
        let far = CalibratedProfile::uniform(
            GPU_ADRENO740,
            RoofParams {
                flops: GPU_ADRENO740.flops,
                bandwidth: GPU_ADRENO740.bandwidth / 4.0,
                dispatch: GPU_ADRENO740.dispatch,
            },
        );
        assert!(close.divergence() < 0.05);
        assert!(far.divergence() > REPLAN_DIVERGENCE);
    }

    #[test]
    fn fleet_calibration_is_shared_across_clones() {
        let fleet = FleetCalibration::with_window(32);
        let clone = fleet.clone();
        clone.record(
            "adreno740",
            &GPU_ADRENO740,
            Observation { class: OpClass::Conv, flops: 1e9, bytes: 1e6, seconds: 1e-3 },
        );
        assert_eq!(fleet.observations("adreno740"), 1);
        assert_eq!(fleet.class_names(), vec!["adreno740".to_string()]);
        assert!(fleet.profile("adreno740").is_some());
        assert!(fleet.profile("bigcore").is_none());
    }
}
