//! The planner: the serving stack's brain.
//!
//! Turns the offline analysis stack (graph IR -> passes -> delegate
//! partition -> roofline cost) into scheduling decisions:
//!
//! * [`registry`] — named device classes covering every shipped
//!   [`crate::delegate::DeviceProfile`] (CLI `--device`, fleet spec
//!   `--fleet`);
//! * [`model`] — representative per-variant component graphs carrying
//!   the paper's delegation pathologies;
//! * [`plan`] — cost-gated pass planning ([`plan_graph`]) and the
//!   per-`(device, variant)` [`ExecutionPlan`] cache ([`PlanRegistry`]):
//!   predicted per-step latency, delegated coverage, peak memory;
//! * [`fleet`] — heterogeneous fleet description ([`FleetSpec`]) and
//!   plan-driven admission routing ([`FleetRouter`]): infeasible
//!   deadlines are rejected at admission, every other request goes to
//!   the cheapest worker class that meets its deadline.  Routing takes
//!   measured per-class request overheads (loads + encode + decode)
//!   over the modeled constant once the fleet has served enough
//!   requests ([`FleetRouter::route_observed`]);
//! * [`calibrate`] — online roofline calibration: per-op-class fits
//!   over the live dispatch stream ([`Calibrator`]), the resulting
//!   [`CalibratedProfile`] overlay, and the shared [`FleetCalibration`]
//!   handle whose divergence drives `PlanRegistry` re-planning.

pub mod calibrate;
pub mod fleet;
pub mod model;
pub mod plan;
pub mod registry;

pub use calibrate::{
    CalibratedProfile, Calibrator, FleetCalibration, Observation,
    DEFAULT_CALIB_WINDOW, MIN_CLASS_SAMPLES, REPLAN_DIVERGENCE,
};
pub use fleet::{FleetRouter, FleetSpec, Route, WorkerClassSpec};
pub use plan::{
    modeled_cost_cal, modeled_cost_s, plan_graph, plan_graph_cal, plan_graph_with,
    schedule_display, ExecutionPlan, PlanRegistry, PlannedGraph, StageSig,
};
pub use registry::{device_names, device_spec, registered_devices, DeviceSpec};
