//! Minimal PNG writer (RGB8, no external deps) + PGM fallback.
//!
//! Used by the examples to materialize generated images (paper Fig. 6).
//! PNG: one IDAT with zlib "stored" (uncompressed) deflate blocks —
//! valid, portable, and dependency-free.

use std::io::Write;
use std::path::Path;

use crate::error::{Error, Result};

/// CRC-32 (IEEE) — required by the PNG container.
fn crc32(data: &[u8]) -> u32 {
    const POLY: u32 = 0xEDB8_8320;
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (POLY & mask);
        }
    }
    !crc
}

/// Adler-32 — required by the zlib wrapper.
fn adler32(data: &[u8]) -> u32 {
    const MOD: u32 = 65521;
    let (mut a, mut b) = (1u32, 0u32);
    for chunk in data.chunks(5550) {
        for &x in chunk {
            a += x as u32;
            b += a;
        }
        a %= MOD;
        b %= MOD;
    }
    (b << 16) | a
}

fn chunk(out: &mut Vec<u8>, tag: &[u8; 4], payload: &[u8]) {
    out.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    out.extend_from_slice(tag);
    out.extend_from_slice(payload);
    let mut crc_in = Vec::with_capacity(4 + payload.len());
    crc_in.extend_from_slice(tag);
    crc_in.extend_from_slice(payload);
    out.extend_from_slice(&crc32(&crc_in).to_be_bytes());
}

/// zlib stream with stored (type-0) deflate blocks.
fn zlib_stored(raw: &[u8]) -> Vec<u8> {
    let mut out = vec![0x78, 0x01]; // CMF/FLG
    let mut rest = raw;
    loop {
        let take = rest.len().min(65535);
        let last = take == rest.len();
        out.push(if last { 1 } else { 0 });
        out.extend_from_slice(&(take as u16).to_le_bytes());
        out.extend_from_slice(&(!(take as u16)).to_le_bytes());
        out.extend_from_slice(&rest[..take]);
        if last {
            break;
        }
        rest = &rest[take..];
    }
    out.extend_from_slice(&adler32(raw).to_be_bytes());
    out
}

/// Write an RGB8 PNG.  `pixels` is HWC row-major, len = w*h*3.
pub fn write_png(path: &Path, w: usize, h: usize, pixels: &[u8]) -> Result<()> {
    if pixels.len() != w * h * 3 {
        return Err(Error::Io(format!(
            "pixel buffer {} != {}x{}x3",
            pixels.len(),
            w,
            h
        )));
    }
    let mut raw = Vec::with_capacity(h * (1 + w * 3));
    for row in 0..h {
        raw.push(0); // filter: none
        raw.extend_from_slice(&pixels[row * w * 3..(row + 1) * w * 3]);
    }
    let mut out = Vec::new();
    out.extend_from_slice(&[0x89, b'P', b'N', b'G', 0x0D, 0x0A, 0x1A, 0x0A]);
    let mut ihdr = Vec::new();
    ihdr.extend_from_slice(&(w as u32).to_be_bytes());
    ihdr.extend_from_slice(&(h as u32).to_be_bytes());
    ihdr.extend_from_slice(&[8, 2, 0, 0, 0]); // 8-bit, RGB
    chunk(&mut out, b"IHDR", &ihdr);
    chunk(&mut out, b"IDAT", &zlib_stored(&raw));
    chunk(&mut out, b"IEND", &[]);
    let mut f = std::fs::File::create(path)?;
    f.write_all(&out)?;
    Ok(())
}

/// Convert [-1, 1]-ish float RGB (HWC) to u8 with clamping.
pub fn float_to_rgb8(data: &[f32]) -> Vec<u8> {
    data.iter()
        .map(|&v| {
            let x = (v * 0.5 + 0.5).clamp(0.0, 1.0);
            (x * 255.0).round() as u8
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc_known_answer() {
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }

    #[test]
    fn adler_known_answer() {
        assert_eq!(adler32(b"Wikipedia"), 0x11E60398);
    }

    #[test]
    fn png_structure() {
        let dir = std::env::temp_dir().join("md_png_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.png");
        let px: Vec<u8> = (0..4 * 4 * 3).map(|i| (i * 7 % 256) as u8).collect();
        write_png(&path, 4, 4, &px).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..8], &[0x89, b'P', b'N', b'G', 0x0D, 0x0A, 0x1A, 0x0A]);
        assert_eq!(&bytes[12..16], b"IHDR");
        assert!(bytes.windows(4).any(|w| w == b"IDAT"));
        assert_eq!(&bytes[bytes.len() - 8..bytes.len() - 4], b"IEND");
    }

    #[test]
    fn float_conversion_clamps() {
        let px = float_to_rgb8(&[-2.0, -1.0, 0.0, 1.0, 2.0]);
        assert_eq!(px, vec![0, 0, 128, 255, 255]);
    }

    #[test]
    fn rejects_bad_sizes() {
        let dir = std::env::temp_dir();
        assert!(write_png(&dir.join("bad.png"), 4, 4, &[0u8; 5]).is_err());
    }
}
