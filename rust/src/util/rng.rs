//! Deterministic PRNG substrate (no `rand` crate offline).
//!
//! SplitMix64 for seeding + xoshiro256++ for the stream, plus a
//! Box-Muller normal sampler — enough for latent initialization, the
//! property-testing engine, and workload generators, all reproducible
//! from a single u64 seed.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller output
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// xoshiro256++ next
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// uniform in [0, 1)
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// uniform integer in [0, n)
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's unbiased bounded sampling (rejection form)
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_hi_lo(x, n);
            if lo >= n || hi < u64::MAX / n * n / n + 1 || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// uniform in [lo, hi] inclusive
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.below(span) as i64
    }

    /// uniform in [lo, hi)
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// standard normal via Box-Muller
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    pub fn normal_f32_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    /// exponential with rate lambda (Poisson inter-arrival times)
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.next_f64().max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// pick a random element
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }
}

fn mul_hi_lo(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            let i = r.range_i64(-5, 5);
            assert!((-5..=5).contains(&i));
        }
    }

    #[test]
    fn below_unbiased_smoke() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((8500..11500).contains(&c), "{:?}", counts);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let m: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.02, "mean {m}");
    }
}
