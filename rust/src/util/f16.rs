//! Software IEEE-754 binary16 — the substrate for reproducing the paper's
//! Sec. 3.2 float16 instability without a mobile GPU.
//!
//! The coordinator uses it to (a) emulate the on-device GELU arithmetic
//! bit-exactly (Fig. 3 divergence, Fig. 8 fix) and (b) account activation
//! bytes in the delegate cost model the way the device stores them.

/// An IEEE-754 half-precision value stored as its bit pattern.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct F16(pub u16);

pub const F16_MAX: f32 = 65504.0;

impl F16 {
    pub const ZERO: F16 = F16(0);
    pub const INFINITY: F16 = F16(0x7C00);
    pub const NEG_INFINITY: F16 = F16(0xFC00);

    /// Round-to-nearest-even conversion from f32.
    pub fn from_f32(x: f32) -> F16 {
        let bits = x.to_bits();
        let sign = ((bits >> 16) & 0x8000) as u16;
        let exp = ((bits >> 23) & 0xFF) as i32;
        let frac = bits & 0x7F_FFFF;

        if exp == 0xFF {
            // inf / nan
            let payload = if frac != 0 { 0x0200 } else { 0 };
            return F16(sign | 0x7C00 | payload | ((frac >> 13) as u16 & 0x03FF));
        }
        // re-bias: f32 exp-127 -> f16 exp-15
        let unbiased = exp - 127;
        if unbiased > 15 {
            return F16(sign | 0x7C00); // overflow -> inf
        }
        if unbiased >= -14 {
            // normal f16
            let exp16 = (unbiased + 15) as u32;
            let mut mant = frac >> 13;
            // round to nearest even on the 13 dropped bits
            let rem = frac & 0x1FFF;
            if rem > 0x1000 || (rem == 0x1000 && (mant & 1) == 1) {
                mant += 1;
            }
            let mut out = (exp16 << 10) + mant; // mantissa carry bumps exp
            if out >= 0x7C00 {
                out = 0x7C00; // rounded up to inf
            }
            return F16(sign | out as u16);
        }
        if unbiased >= -25 {
            // subnormal f16
            let shift = (-unbiased - 14 + 13) as u32; // 14..24
            let full = frac | 0x80_0000; // implicit leading 1
            let mut mant = full >> shift;
            let rem = full & ((1 << shift) - 1);
            let half = 1u32 << (shift - 1);
            if rem > half || (rem == half && (mant & 1) == 1) {
                mant += 1;
            }
            return F16(sign | mant as u16);
        }
        F16(sign) // underflow -> signed zero
    }

    pub fn to_f32(self) -> f32 {
        let sign = ((self.0 & 0x8000) as u32) << 16;
        let exp = ((self.0 >> 10) & 0x1F) as u32;
        let mant = (self.0 & 0x3FF) as u32;
        let bits = if exp == 0x1F {
            sign | 0x7F80_0000 | (mant << 13)
        } else if exp == 0 {
            if mant == 0 {
                sign
            } else {
                // subnormal: normalize
                let mut e = 0i32;
                let mut m = mant;
                while m & 0x400 == 0 {
                    m <<= 1;
                    e -= 1;
                }
                m &= 0x3FF;
                sign | (((112 + e + 1) as u32) << 23) | (m << 13)
            }
        } else {
            sign | ((exp + 112) << 23) | (mant << 13)
        };
        f32::from_bits(bits)
    }

    pub fn is_finite(self) -> bool {
        (self.0 & 0x7C00) != 0x7C00
    }
    pub fn is_infinite(self) -> bool {
        (self.0 & 0x7FFF) == 0x7C00
    }
    pub fn is_nan(self) -> bool {
        (self.0 & 0x7C00) == 0x7C00 && (self.0 & 0x03FF) != 0
    }
}

/// Emulated f16 arithmetic: compute in f32, round back after every op —
/// the semantics of a mobile GPU's native half ALU.
pub fn add(a: F16, b: F16) -> F16 {
    F16::from_f32(a.to_f32() + b.to_f32())
}
pub fn mul(a: F16, b: F16) -> F16 {
    F16::from_f32(a.to_f32() * b.to_f32())
}
pub fn tanh(a: F16) -> F16 {
    F16::from_f32(a.to_f32().tanh())
}
pub fn clamp(a: F16, lo: f32, hi: f32) -> F16 {
    F16::from_f32(a.to_f32().clamp(lo, hi))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_integers() {
        for i in -2048..=2048 {
            let h = F16::from_f32(i as f32);
            assert_eq!(h.to_f32(), i as f32, "i={}", i);
        }
    }

    #[test]
    fn known_bit_patterns() {
        assert_eq!(F16::from_f32(1.0).0, 0x3C00);
        assert_eq!(F16::from_f32(-2.0).0, 0xC000);
        assert_eq!(F16::from_f32(65504.0).0, 0x7BFF);
        assert_eq!(F16::from_f32(0.5).0, 0x3800);
        assert_eq!(F16::from_f32(6.1035156e-5).0, 0x0400); // min normal
    }

    #[test]
    fn overflow_to_inf() {
        assert!(F16::from_f32(65520.0).is_infinite());
        assert!(F16::from_f32(1e6).is_infinite());
        assert!(!F16::from_f32(65504.0).is_infinite());
        assert!(F16::from_f32(-70000.0) == F16::NEG_INFINITY);
    }

    #[test]
    fn subnormals_round_trip() {
        let tiny = 5.9604645e-8; // min subnormal
        assert_eq!(F16::from_f32(tiny).0, 0x0001);
        assert_eq!(F16(0x0001).to_f32(), tiny);
        assert_eq!(F16::from_f32(tiny / 3.0).0, 0x0000); // underflow
    }

    #[test]
    fn round_to_nearest_even() {
        // 2049 is exactly between 2048 and 2050 in f16 -> rounds to even 2048
        assert_eq!(F16::from_f32(2049.0).to_f32(), 2048.0);
        assert_eq!(F16::from_f32(2051.0).to_f32(), 2052.0);
    }

    #[test]
    fn nan_propagation() {
        assert!(F16::from_f32(f32::NAN).is_nan());
        assert!(F16::from_f32(f32::NAN).to_f32().is_nan());
    }

    #[test]
    fn round_trip_all_finite_f16() {
        // every finite f16 bit pattern must survive f16 -> f32 -> f16
        for bits in 0u16..=0xFFFF {
            let h = F16(bits);
            if h.is_nan() {
                continue;
            }
            let back = F16::from_f32(h.to_f32());
            assert_eq!(back.0, bits, "bits={:#06x}", bits);
        }
    }

    #[test]
    fn cube_overflow_threshold_matches_paper() {
        // x^3 overflows f16 just above 40.3 (65504^(1/3))
        let below = mul(mul(F16::from_f32(40.28), F16::from_f32(40.28)),
                        F16::from_f32(40.28));
        let above = mul(mul(F16::from_f32(40.4), F16::from_f32(40.4)),
                        F16::from_f32(40.4));
        assert!(below.is_finite());
        assert!(above.is_infinite());
    }
}
