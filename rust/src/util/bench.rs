//! Micro-benchmark harness (criterion substitute for the offline build).
//!
//! Warmup + timed iterations with mean/p50/p99 reporting; used by every
//! `cargo bench` target (`harness = false`) and by the perf pass.

use std::time::Instant;

use super::stats::{summarize, Summary};

#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub iters: usize,
    /// cap total measured time; stops early past this many seconds
    pub max_seconds: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, iters: 30, max_seconds: 10.0 }
    }
}

pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.summary.mean * 1e3
    }
}

/// Time `f` under `cfg`, returning per-iteration wall-clock seconds.
pub fn bench_with<F: FnMut()>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.iters);
    let start = Instant::now();
    for _ in 0..cfg.iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if start.elapsed().as_secs_f64() > cfg.max_seconds && samples.len() >= 5 {
            break;
        }
    }
    BenchResult { name: name.to_string(), summary: summarize(&samples) }
}

pub fn bench<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench_with(name, &BenchConfig::default(), f)
}

/// Standard row printer shared by all bench targets.
pub fn print_result(r: &BenchResult) {
    let s = &r.summary;
    println!(
        "{:<44} mean {:>9.3} ms   p50 {:>9.3} ms   p99 {:>9.3} ms   (n={})",
        r.name,
        s.mean * 1e3,
        s.p50 * 1e3,
        s.p99 * 1e3,
        s.count
    );
}

pub fn print_header(title: &str) {
    println!("\n=== {} ===", title);
}

/// A black-box to keep the optimizer from eliding benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench_with(
            "t",
            &BenchConfig { warmup_iters: 1, iters: 5, max_seconds: 5.0 },
            || {
                let mut s = 0u64;
                for i in 0..10_000 {
                    s = s.wrapping_add(black_box(i));
                }
                black_box(s);
            },
        );
        assert_eq!(r.summary.count, 5);
        assert!(r.summary.mean > 0.0);
    }

    #[test]
    fn early_stop_on_budget() {
        let r = bench_with(
            "slow",
            &BenchConfig { warmup_iters: 0, iters: 1000, max_seconds: 0.05 },
            || std::thread::sleep(std::time::Duration::from_millis(2)),
        );
        assert!(r.summary.count < 1000);
        assert!(r.summary.count >= 5);
    }
}
