//! Hand-rolled substrates (the offline build vendors no serde / rand /
//! half / criterion / proptest — see DESIGN.md):
//!
//! * [`json`]     — JSON parser + writer (manifest, graph specs, reports)
//! * [`rng`]      — SplitMix64/xoshiro256++ + normal sampler
//! * [`f16`]      — software IEEE binary16 (the Sec. 3.2 experiments)
//! * [`stats`]    — latency summaries, MSE / PSNR
//! * [`image`]    — PNG (+ PGM) writer for generated images
//! * [`bench`]    — micro-benchmark harness (criterion substitute)
//! * [`miniprop`] — tiny property-testing engine (proptest substitute)

pub mod bench;
pub mod f16;
pub mod image;
pub mod json;
pub mod miniprop;
pub mod rng;
pub mod stats;
