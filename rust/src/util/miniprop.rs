//! Tiny property-testing engine (proptest substitute for the offline
//! build): seeded case generation with input shrinking on failure.
//!
//! Usage (no_run: rustdoc test binaries miss the xla rpath in this
//! offline image; the same example runs as a unit test below):
//! ```no_run
//! use mobile_diffusion::util::miniprop::{forall, Gen};
//! forall("add commutes", 100, |g: &mut Gen| {
//!     let a = g.int(0, 1000);
//!     let b = g.int(0, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Rng;

/// Case-local generator handed to the property body.
pub struct Gen {
    rng: Rng,
    /// log of drawn values for reporting
    pub log: Vec<(String, String)>,
}

impl Gen {
    fn new(seed: u64) -> Gen {
        Gen { rng: Rng::new(seed), log: Vec::new() }
    }

    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        let v = self.rng.range_i64(lo, hi);
        self.log.push(("int".into(), v.to_string()));
        v
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.int(lo as i64, hi as i64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.uniform(lo, hi);
        self.log.push(("f64".into(), format!("{v}")));
        v
    }

    pub fn f32_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        let v: Vec<f32> =
            (0..n).map(|_| self.rng.normal() as f32 * scale).collect();
        self.log.push(("vec".into(), format!("len {n} scale {scale}")));
        v
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        let i = self.rng.below(items.len() as u64) as usize;
        self.log.push(("choice".into(), i.to_string()));
        &items[i]
    }

    pub fn bool(&mut self) -> bool {
        self.int(0, 1) == 1
    }

    pub fn seed(&mut self) -> u64 {
        self.rng.next_u64()
    }
}

/// Run `body` over `cases` generated inputs.  On panic, re-runs nearby
/// seeds to find a smaller failing case (shrink-lite: we cannot shrink
/// structurally without capturing the generator tree, but low seeds
/// produce small values by construction in our generators), then panics
/// with the failing seed so the case is reproducible.
pub fn forall<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    name: &str,
    cases: u64,
    body: F,
) {
    for case in 0..cases {
        let seed = 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(case + 1);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed);
            body(&mut g);
        });
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        forall("abs is non-negative", 200, |g| {
            let v = g.int(-1000, 1000);
            assert!(v.abs() >= 0);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failures() {
        forall("always fails", 10, |g| {
            let v = g.int(0, 10);
            assert!(v > 100, "v = {v}");
        });
    }

    #[test]
    fn deterministic_cases() {
        use std::sync::Mutex;
        let first = Mutex::new(Vec::new());
        forall("collect", 5, |g| {
            first.lock().unwrap().push(g.int(0, 1_000_000));
        });
        // same seeds -> same values on a second identical run
        let second = Mutex::new(Vec::new());
        forall("collect again", 5, |g| {
            second.lock().unwrap().push(g.int(0, 1_000_000));
        });
        assert_eq!(*first.lock().unwrap(), *second.lock().unwrap());
    }
}
