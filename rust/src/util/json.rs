//! Minimal JSON parser + writer.
//!
//! The offline build environment vendors no serde, so the coordinator
//! carries its own JSON substrate: a recursive-descent parser producing a
//! dynamically-typed [`Json`] tree, plus a compact writer.  It supports
//! the full JSON grammar (RFC 8259) minus exotic number forms beyond f64.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors --------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| if n >= 0.0 { Some(n as usize) } else { None })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; returns `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }
    /// Array index lookup.
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|a| a.get(idx)).unwrap_or(&NULL)
    }
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // -- construction helpers ----------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad utf8 in number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // surrogate pairs
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("bad codepoint"))?);
                            continue;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("short \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad utf8 in \\u"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("a").at(2).get("b"), &Json::Bool(false));
        assert_eq!(j.get("c").as_str(), Some("x"));
        assert!(j.get("missing").is_null());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""é""#).unwrap().as_str(), Some("é"));
        assert_eq!(
            Json::parse(r#""😀""#).unwrap().as_str(),
            Some("😀")
        );
    }

    #[test]
    fn round_trip() {
        let src = r#"{"arr":[1,2.5,null,true],"s":"hi\n"}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn big_manifest_like() {
        let mut s = String::from("[");
        for i in 0..1000 {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("{{\"i\":{},\"v\":{}.5}}", i, i));
        }
        s.push(']');
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.as_arr().unwrap().len(), 1000);
        assert_eq!(j.at(999).get("i").as_i64(), Some(999));
    }
}
