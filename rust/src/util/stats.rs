//! Latency/throughput statistics for metrics and the bench harness.

#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Compute order statistics over a sample (nearest-rank percentiles).
pub fn summarize(samples: &[f64]) -> Summary {
    if samples.is_empty() {
        return Summary::default();
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len();
    let mean = sorted.iter().sum::<f64>() / n as f64;
    let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / n.max(1) as f64;
    let pct = |p: f64| -> f64 {
        let rank = ((p / 100.0) * n as f64).ceil().max(1.0) as usize;
        sorted[rank.min(n) - 1]
    };
    Summary {
        count: n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: pct(50.0),
        p90: pct(90.0),
        p95: pct(95.0),
        p99: pct(99.0),
    }
}

/// Mean squared error between two equal-length slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = *x as f64 - *y as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Peak signal-to-noise ratio in dB over a given dynamic range.
pub fn psnr(a: &[f32], b: &[f32], peak: f64) -> f64 {
    let e = mse(a, b);
    if e == 0.0 {
        return f64::INFINITY;
    }
    10.0 * (peak * peak / e).log10()
}

pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (*x as f64 - *y as f64).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = summarize(&xs);
        assert_eq!(s.p50, 50.0);
        assert_eq!(s.p90, 90.0);
        assert_eq!(s.p95, 95.0);
        assert_eq!(s.p99, 99.0);
    }

    #[test]
    fn empty_is_safe() {
        let s = summarize(&[]);
        assert_eq!(s.count, 0);
    }

    #[test]
    fn mse_and_psnr() {
        let a = [0.0f32, 0.0];
        let b = [1.0f32, 1.0];
        assert!((mse(&a, &b) - 1.0).abs() < 1e-12);
        assert!((psnr(&a, &b, 1.0) - 0.0).abs() < 1e-9);
        assert_eq!(psnr(&a, &a, 1.0), f64::INFINITY);
    }

    #[test]
    fn max_abs() {
        assert_eq!(max_abs_diff(&[1.0, -3.0], &[1.5, 0.0]), 3.0);
    }
}
